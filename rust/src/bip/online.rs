//! Algorithm 3: online BIP-Based Balancing on one routing gate.
//!
//! Tokens arrive one at a time; the gate routes immediately (Topk of
//! s - q) and then refines the duals. Each expert keeps the (cap+1)
//! largest reduced scores seen so far in a bounded min-heap, so the
//! (nk/m + 1)-th order statistic of Q_j ∪ {s_j - p} is answered in O(1)
//! and maintained in O(log n) — the paper's O(m log n) per token.
//!
//! This is the variant §5.1 proposes for multi-slot online matching /
//! recommendation; the `matching` module drives it on an ad-slot workload.

use crate::util::stats::{kth_largest_in_place, topk_indices, topk_into};

/// Bounded min-heap holding the `bound` largest values ever pushed.
/// Answers min (the bound-th largest) and second-min in O(1).
#[derive(Clone, Debug)]
pub struct TopHeap {
    bound: usize,
    heap: Vec<f32>, // binary min-heap
}

impl TopHeap {
    pub fn new(bound: usize) -> Self {
        assert!(bound >= 1);
        TopHeap { bound, heap: Vec::with_capacity(bound + 1) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.heap.len() >= self.bound
    }

    /// Minimum of the kept values = bound-th largest seen (when full).
    pub fn min(&self) -> Option<f32> {
        self.heap.first().copied()
    }

    /// Second-smallest kept value (min of the root's children).
    pub fn second_min(&self) -> Option<f32> {
        match self.heap.len() {
            0 | 1 => None,
            // LINT-ALLOW(panic): the match arm proves len == 2
            2 => Some(self.heap[1]),
            // LINT-ALLOW(panic): the match arm proves len >= 3
            _ => Some(self.heap[1].min(self.heap[2])),
        }
    }

    /// `bound`-th largest of kept ∪ {x} WITHOUT inserting x.
    /// None when even with x there are fewer than `bound` values.
    pub fn kth_largest_with(&self, x: f32) -> Option<f32> {
        if self.heap.len() + 1 < self.bound {
            return None;
        }
        if self.heap.len() + 1 == self.bound {
            // exactly bound values: the bound-th largest is the minimum
            return Some(self.min().map_or(x, |m| m.min(x)));
        }
        // LINT-ALLOW(panic): len + 1 > bound >= 1 here, so the heap
        // is non-empty
        let m = self.min().unwrap();
        if x <= m {
            Some(m)
        } else {
            // x displaces the current min from the top-bound set
            Some(self.second_min().map_or(x, |s2| s2.min(x)))
        }
    }

    /// The kept values, unordered (heap layout) — the mergeable payload
    /// the replica-sync protocol ships between gates.
    pub fn values(&self) -> &[f32] {
        &self.heap
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Insert permanently, evicting the smallest if over bound.
    pub fn push(&mut self, x: f32) {
        if self.heap.len() < self.bound {
            self.heap.push(x);
            self.sift_up(self.heap.len() - 1);
            return;
        }
        // LINT-ALLOW(panic): bound >= 1 and the heap is full here, so
        // heap[0] (the current minimum) exists
        if x > self.heap[0] {
            // LINT-ALLOW(panic): full heap, see the guard above
            self.heap[0] = x;
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i] < self.heap[parent] {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < self.heap.len() && self.heap[l] < self.heap[smallest] {
                smallest = l;
            }
            if r < self.heap.len() && self.heap[r] < self.heap[smallest] {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

/// Online gate state (Algorithm 3): duals q plus per-expert top-heaps.
pub struct OnlineGate {
    pub m: usize,
    pub k: usize,
    /// capacity rate cap = n*k/m from the batch-size parameter n
    pub cap: usize,
    pub t_iters: usize,
    pub q: Vec<f32>,
    heaps: Vec<TopHeap>,
    scratch: Vec<f32>,
}

impl OnlineGate {
    pub fn new(m: usize, k: usize, cap: usize, t_iters: usize) -> Self {
        OnlineGate {
            m,
            k,
            cap,
            t_iters,
            q: vec![0.0; m],
            heaps: (0..m).map(|_| TopHeap::new(cap + 1)).collect(),
            scratch: vec![0.0; m],
        }
    }

    /// Process one arriving token: route it (Topk of s - q), then run the
    /// T-iteration refinement and absorb the reduced scores into Q.
    /// Returns the chosen expert ids.
    // COLD: allocating compat seam — serving routes through
    // `route_token_into`; the static hot-path lint stops here
    pub fn route_token(&mut self, scores: &[f32]) -> Vec<u32> {
        assert_eq!(scores.len(), self.m);
        for j in 0..self.m {
            self.scratch[j] = scores[j] - self.q[j];
        }
        let chosen: Vec<u32> = topk_indices(&self.scratch, self.k)
            .into_iter()
            .map(|e| e as u32)
            .collect();
        self.refine_and_absorb(scores);
        chosen
    }

    /// Allocation-free [`OnlineGate::route_token`]: the chosen experts
    /// go into `out[..len]` using the caller's `idx` scratch
    /// (`idx.len() == m`). Identical decisions and identical dual/heap
    /// updates — the top-k comparator is a total order, so both paths
    /// select the same set in the same order.
    pub fn route_token_into(
        &mut self,
        scores: &[f32],
        idx: &mut [u32],
        out: &mut [u32],
    ) -> usize {
        assert_eq!(scores.len(), self.m);
        for j in 0..self.m {
            self.scratch[j] = scores[j] - self.q[j];
        }
        let len = topk_into(&self.scratch, self.k, idx, out);
        self.refine_and_absorb(scores);
        len
    }

    /// Lines 7-14 for one token: the T-iteration dual refinement, then
    /// absorb the reduced scores into every expert's top-heap.
    fn refine_and_absorb(&mut self, scores: &[f32]) {
        let kk = (self.k + 1).min(self.m);
        let mut p = 0.0f32;
        for _ in 0..self.t_iters {
            // p = max(0, (k+1)-th largest of {s_l - q_l})
            for j in 0..self.m {
                self.scratch[j] = scores[j] - self.q[j];
            }
            p = kth_largest_in_place(&mut self.scratch, kk).max(0.0);
            // q_j = max(0, (cap+1)-th largest of Q_j ∪ {s_j - p})
            for j in 0..self.m {
                self.q[j] = self.heaps[j]
                    .kth_largest_with(scores[j] - p)
                    .unwrap_or(0.0)
                    .max(0.0);
            }
        }
        // line 14: Q_j <- Q_j ∪ {s_j - p}
        for j in 0..self.m {
            self.heaps[j].push(scores[j] - p);
        }
    }

    /// Contents of every expert's top-heap (unordered), for replica
    /// state export.
    pub fn heap_values(&self) -> Vec<Vec<f32>> {
        self.heaps.iter().map(|h| h.values().to_vec()).collect()
    }

    /// Rebuild every heap from the given per-expert value multisets.
    /// The bounded push keeps exactly the `cap+1` largest of each
    /// multiset, whatever the insertion order — so a union of replica
    /// heaps merges deterministically and stays bounded across syncs.
    pub fn rebuild_heaps(&mut self, values: &[Vec<f32>]) {
        assert_eq!(values.len(), self.heaps.len());
        for (h, vals) in self.heaps.iter_mut().zip(values) {
            h.clear();
            for &v in vals {
                h.push(v);
            }
        }
    }

    /// Bytes of state held (the O(n k) growth §5.2 worries about).
    pub fn state_bytes(&self) -> usize {
        self.heaps.iter().map(|h| h.len() * 4).sum::<usize>()
            + self.q.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bip::Instance;
    use crate::util::rng::Pcg64;

    #[test]
    fn topheap_order_statistics_match_sort() {
        let mut rng = Pcg64::new(1);
        for bound in [1usize, 2, 3, 8] {
            let mut heap = TopHeap::new(bound);
            let mut seen: Vec<f32> = Vec::new();
            for _ in 0..200 {
                let x = rng.next_f32();
                // query before insert
                let got = heap.kth_largest_with(x);
                let mut all = seen.clone();
                all.push(x);
                all.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let want = if all.len() >= bound {
                    Some(all[bound - 1])
                } else {
                    None
                };
                assert_eq!(got, want, "bound={bound} n={}", seen.len());
                heap.push(x);
                seen.push(x);
                // heap min == bound-th largest of seen
                if seen.len() >= bound {
                    let mut s = seen.clone();
                    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
                    assert_eq!(heap.min(), Some(s[bound - 1]));
                }
            }
        }
    }

    #[test]
    fn online_balances_a_skewed_stream() {
        let mut rng = Pcg64::new(2);
        let (n, m, k) = (1024usize, 16usize, 4usize);
        let inst = Instance::synthetic(n, m, k, 2.0, 3.0, &mut rng);
        let mut gate = OnlineGate::new(m, k, n * k / m, 4);
        let mut loads = vec![0u32; m];
        let mut greedy_loads = vec![0u32; m];
        // also track the steady-state tail: the cold-start transient is
        // expected (q needs arrivals to learn), the paper's claim is about
        // the balanced steady state
        let mut tail_loads = vec![0u32; m];
        for i in 0..n {
            for &e in &gate.route_token(inst.row(i)) {
                loads[e as usize] += 1;
                if i >= 3 * n / 4 {
                    tail_loads[e as usize] += 1;
                }
            }
            for e in crate::util::stats::topk_indices(inst.row(i), k) {
                greedy_loads[e] += 1;
            }
        }
        let mean = (n * k / m) as f64;
        let vio = *loads.iter().max().unwrap() as f64 / mean - 1.0;
        let gvio = *greedy_loads.iter().max().unwrap() as f64 / mean - 1.0;
        assert!(vio < gvio, "online {vio} greedy {gvio}");
        let tail_mean = (n / 4 * k) as f64 / m as f64;
        let tail_vio =
            *tail_loads.iter().max().unwrap() as f64 / tail_mean - 1.0;
        assert!(tail_vio < vio, "steady state must improve: tail \
                {tail_vio} overall {vio}");
        assert!(tail_vio < 0.6, "steady-state vio too high: {tail_vio}");
    }

    #[test]
    fn routes_k_distinct_experts_per_token() {
        let mut rng = Pcg64::new(3);
        let inst = Instance::synthetic(64, 8, 3, 2.0, 1.0, &mut rng);
        let mut gate = OnlineGate::new(8, 3, 24, 2);
        for i in 0..inst.n {
            let chosen = gate.route_token(inst.row(i));
            assert_eq!(chosen.len(), 3);
            let mut c = chosen.clone();
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), 3);
        }
    }

    #[test]
    fn state_grows_linearly_until_heap_bound() {
        let mut rng = Pcg64::new(4);
        let (m, k, cap) = (8usize, 2usize, 16usize);
        let mut gate = OnlineGate::new(m, k, cap, 2);
        let mut sizes = Vec::new();
        for i in 0..200 {
            let inst = Instance::synthetic(1, m, k, 2.0, 0.0, &mut rng);
            gate.route_token(inst.row(0));
            if i % 50 == 0 {
                sizes.push(gate.state_bytes());
            }
        }
        // bounded by m * (cap+1) floats + q
        assert!(*sizes.last().unwrap() <= (m * (cap + 1) + m) * 4);
        assert!(sizes[0] < *sizes.last().unwrap());
    }
}
