//! The optimization substrate for BIP-Based Balancing (paper §3 and §5).
//!
//! * [`flow`]    — exact min-cost max-flow; the routing BIP is a
//!   transportation LP with integral vertices, so this is the *exact*
//!   optimum the paper's primal-dual heuristic is measured against.
//! * [`dual`]    — Algorithm 1 lines 7-12: the T-iteration dual ascent
//!   (host-side mirror of the L1 Pallas kernel, bit-compatible).
//! * [`online`]  — Algorithm 3: streaming per-token version with
//!   per-expert bounded heaps (O(m log n) per token).
//! * [`approx`]  — Algorithm 4: constant-space variant with b-bucket
//!   histograms + interpolation (O(m·b) space, no dependence on n).
//!
//! All solvers share the [`Instance`]/[`Routing`] vocabulary below.

pub mod approx;
pub mod dual;
pub mod flow;
pub mod online;

use crate::util::rng::Pcg64;

/// One routing problem: n tokens, m experts, k choices per token, and the
/// per-expert capacity `cap` = n*k/m of BIP constraint (2).
#[derive(Clone, Debug)]
pub struct Instance {
    pub n: usize,
    pub m: usize,
    pub k: usize,
    pub cap: usize,
    /// Row-major (n, m) routing scores (softmax rows in the LLM setting).
    pub scores: Vec<f32>,
}

impl Instance {
    pub fn score(&self, i: usize, j: usize) -> f32 {
        self.scores[i * self.m + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.scores[i * self.m..(i + 1) * self.m]
    }

    /// Softmax-score instance with optional expert-popularity skew — the
    /// hard case where every token prefers the same experts.
    pub fn synthetic(
        n: usize,
        m: usize,
        k: usize,
        temp: f64,
        skew: f64,
        rng: &mut Pcg64,
    ) -> Instance {
        let mut scores = Vec::with_capacity(n * m);
        for _ in 0..n {
            let mut logits: Vec<f64> = (0..m)
                .map(|j| {
                    rng.normal() * temp
                        + skew * (m - 1 - j) as f64 / (m - 1).max(1) as f64
                })
                .collect();
            let maxv = logits.iter().cloned().fold(f64::MIN, f64::max);
            let mut total = 0.0;
            for l in logits.iter_mut() {
                *l = (*l - maxv).exp();
                total += *l;
            }
            for l in &logits {
                scores.push((l / total) as f32);
            }
        }
        Instance { n, m, k, cap: n * k / m, scores }
    }
}

/// A complete routing decision: for each token, its k chosen experts.
#[derive(Clone, Debug)]
pub struct Routing {
    pub assignment: Vec<Vec<u32>>, // token -> expert ids (len k, or fewer)
}

impl Routing {
    /// Per-expert load histogram.
    pub fn loads(&self, m: usize) -> Vec<u32> {
        let mut loads = vec![0u32; m];
        for experts in &self.assignment {
            for &e in experts {
                loads[e as usize] += 1;
            }
        }
        loads
    }

    /// Sum of selected scores — the BIP objective.
    pub fn objective(&self, inst: &Instance) -> f64 {
        self.assignment
            .iter()
            .enumerate()
            .flat_map(|(i, es)| {
                es.iter().map(move |&e| inst.score(i, e as usize) as f64)
            })
            .sum()
    }

    /// MaxVio = max_j load_j / (n k / m) - 1 (Wang et al. 2024).
    pub fn max_violation(&self, inst: &Instance) -> f64 {
        let loads = self.loads(inst.m);
        let mean = inst.n as f64 * inst.k as f64 / inst.m as f64;
        loads.iter().cloned().max().unwrap_or(0) as f64 / mean - 1.0
    }

    pub fn is_row_feasible(&self, k: usize) -> bool {
        self.assignment.iter().all(|es| es.len() <= k)
    }

    pub fn is_col_feasible(&self, m: usize, cap: usize) -> bool {
        self.loads(m).iter().all(|&l| l as usize <= cap)
    }
}

/// Greedy top-k on raw scores (the unbalanced baseline every method is
/// compared against).
pub fn greedy_topk(inst: &Instance) -> Routing {
    let assignment = (0..inst.n)
        .map(|i| {
            crate::util::stats::topk_indices(inst.row(i), inst.k)
                .into_iter()
                .map(|e| e as u32)
                .collect()
        })
        .collect();
    Routing { assignment }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_rows_are_softmax() {
        let mut rng = Pcg64::new(0);
        let inst = Instance::synthetic(32, 8, 2, 2.0, 1.0, &mut rng);
        for i in 0..inst.n {
            let sum: f32 = inst.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(inst.row(i).iter().all(|&s| s >= 0.0));
        }
    }

    #[test]
    fn greedy_is_row_feasible_and_maximal() {
        let mut rng = Pcg64::new(1);
        let inst = Instance::synthetic(64, 8, 3, 2.0, 0.0, &mut rng);
        let routing = greedy_topk(&inst);
        assert!(routing.is_row_feasible(inst.k));
        assert_eq!(routing.loads(inst.m).iter().sum::<u32>(),
                   (inst.n * inst.k) as u32);
        // per-token: selected sum >= any other k-subset's sum
        for i in 0..inst.n {
            let sel: f64 = routing.assignment[i]
                .iter()
                .map(|&e| inst.score(i, e as usize) as f64)
                .sum();
            let mut row: Vec<f32> = inst.row(i).to_vec();
            row.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let best: f64 = row[..inst.k].iter().map(|&x| x as f64).sum();
            assert!((sel - best).abs() < 1e-6);
        }
    }

    #[test]
    fn skew_makes_greedy_unbalanced() {
        let mut rng = Pcg64::new(2);
        let skewed = Instance::synthetic(256, 16, 4, 1.0, 4.0, &mut rng);
        let flat = Instance::synthetic(256, 16, 4, 1.0, 0.0, &mut rng);
        let vs = greedy_topk(&skewed).max_violation(&skewed);
        let vf = greedy_topk(&flat).max_violation(&flat);
        assert!(vs > vf, "skewed {vs} flat {vf}");
        assert!(vs > 1.0);
    }
}
