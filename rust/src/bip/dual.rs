//! Algorithm 1 (lines 7-12): the T-iteration primal-dual / ADMM update —
//! host-side mirror of the L1 Pallas kernel, same order statistics, same
//! tie-breaking, so the two implementations are interchangeable (verified
//! against the kernel through the artifact-equivalence integration test).
//!
//! Per iteration, with scratch buffers reused across calls:
//!   p_i = max(0, (k+1)-th largest of  s_i· - q)        [token duals]
//!   q_j = max(0, (cap+1)-th largest of s_·j - p)       [expert duals]
//!
//! Complexity: O(T · n · m) with quickselect (no sort), ~microseconds for
//! the paper's gate sizes — the "very small time costs" claim the solver
//! bench quantifies.

use std::sync::Mutex;

use super::{Instance, Routing};
use crate::util::pool::Pool;
use crate::util::stats::{
    f32_order_key, kth_largest_keys, topk_indices,
};

/// Reusable solver state: the warm-started dual vector q (Alg. 1 line 2
/// initializes it once per gate, NOT once per batch) plus scratch space.
#[derive(Clone, Debug)]
pub struct DualState {
    pub q: Vec<f32>,
    /// order-key scratch: quickselect partitions on u32 keys instead of
    /// f32 partial_cmp — the solver's hot path (EXPERIMENTS.md §Perf)
    scratch_row: Vec<u32>,
    scratch_col: Vec<u32>,
    /// column-major copy of the current batch's scores so the q-phase
    /// reads expert columns sequentially
    scores_t: Vec<f32>,
    pub p: Vec<f32>,
}

impl DualState {
    pub fn new(m: usize) -> Self {
        DualState {
            q: vec![0.0; m],
            scratch_row: Vec::new(),
            scratch_col: Vec::new(),
            scores_t: Vec::new(),
            p: Vec::new(),
        }
    }

    /// Run T dual iterations against one batch's scores (Alg. 1 lines 7-12).
    pub fn update(&mut self, inst: &Instance, t_iters: usize) {
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        self.scratch_row.resize(m, 0);
        self.scratch_col.resize(n, 0);
        // transpose once per batch
        self.scores_t.resize(n * m, 0.0);
        for i in 0..n {
            let row = inst.row(i);
            for j in 0..m {
                self.scores_t[j * n + i] = row[j];
            }
        }
        for _ in 0..t_iters {
            // p_i = max(0, (k+1)-th largest of s_i - q)
            for i in 0..n {
                let row = inst.row(i);
                for j in 0..m {
                    self.scratch_row[j] =
                        f32_order_key(row[j] - self.q[j]);
                }
                self.p[i] =
                    kth_largest_keys(&mut self.scratch_row, kk).max(0.0);
            }
            // q_j = max(0, (cap+1)-th largest of s_·j - p)
            for j in 0..m {
                let col = &self.scores_t[j * n..(j + 1) * n];
                for i in 0..n {
                    self.scratch_col[i] =
                        f32_order_key(col[i] - self.p[i]);
                }
                self.q[j] =
                    kth_largest_keys(&mut self.scratch_col, cc).max(0.0);
            }
        }
    }

    /// Shared-pool variant of [`DualState::update`]: the p-phase is
    /// chunked over token rows and the q-phase over expert columns.
    /// Every chunk evaluates exactly the serial per-element recurrence
    /// (a quickselect over the same multiset yields the same order
    /// statistic regardless of partitioning), so `q`, `p` and the
    /// subsequent routing are bit-identical to the serial path — the
    /// equivalence tests pin this.
    pub fn update_parallel(
        &mut self,
        inst: &Instance,
        t_iters: usize,
        pool: &Pool,
    ) {
        if pool.threads() <= 1 {
            return self.update(inst, t_iters);
        }
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        // the serial path keeps these as persistent scratch; size them
        // identically so state_bytes() reports the same footprint on
        // either path
        self.scratch_row.resize(m, 0);
        self.scratch_col.resize(n, 0);
        self.scores_t.resize(n * m, 0.0);
        let row_chunks = chunk_bounds(n, pool.threads());
        let col_chunks = chunk_bounds(m, pool.threads());
        // each phase gathers per-chunk results through a Mutex and
        // copies them back — one extra O(len) copy and a handful of
        // small allocations per phase, deliberately paid to keep the
        // chunk jobs free of aliased &mut into self (the quickselect
        // itself is O(n·m) per iteration and dominates)

        // transpose once per batch, column blocks in parallel
        {
            let parts: Mutex<Vec<Option<Vec<f32>>>> =
                Mutex::new(vec![None; col_chunks.len()]);
            let job = |c: usize| {
                let (j0, j1) = col_chunks[c];
                let mut block = vec![0.0f32; (j1 - j0) * n];
                for i in 0..n {
                    let row = inst.row(i);
                    for j in j0..j1 {
                        block[(j - j0) * n + i] = row[j];
                    }
                }
                parts.lock().unwrap()[c] = Some(block);
            };
            pool.scoped_run(col_chunks.len(), &job);
            let parts = parts.into_inner().unwrap();
            for (c, part) in parts.into_iter().enumerate() {
                let (j0, j1) = col_chunks[c];
                self.scores_t[j0 * n..j1 * n]
                    .copy_from_slice(&part.expect("transpose chunk"));
            }
        }

        for _ in 0..t_iters {
            // p_i = max(0, (k+1)-th largest of s_i - q): rows are
            // independent given q
            {
                let q = &self.q;
                let parts: Mutex<Vec<Option<Vec<f32>>>> =
                    Mutex::new(vec![None; row_chunks.len()]);
                let job = |c: usize| {
                    let (i0, i1) = row_chunks[c];
                    let mut keys = vec![0u32; m];
                    let mut vals = vec![0.0f32; i1 - i0];
                    for i in i0..i1 {
                        let row = inst.row(i);
                        for j in 0..m {
                            keys[j] = f32_order_key(row[j] - q[j]);
                        }
                        vals[i - i0] =
                            kth_largest_keys(&mut keys, kk).max(0.0);
                    }
                    parts.lock().unwrap()[c] = Some(vals);
                };
                pool.scoped_run(row_chunks.len(), &job);
                let parts = parts.into_inner().unwrap();
                for (c, part) in parts.into_iter().enumerate() {
                    let (i0, i1) = row_chunks[c];
                    self.p[i0..i1]
                        .copy_from_slice(&part.expect("p chunk"));
                }
            }
            // q_j = max(0, (cap+1)-th largest of s_·j - p): columns are
            // independent given p
            {
                let p = &self.p;
                let scores_t = &self.scores_t;
                let parts: Mutex<Vec<Option<Vec<f32>>>> =
                    Mutex::new(vec![None; col_chunks.len()]);
                let job = |c: usize| {
                    let (j0, j1) = col_chunks[c];
                    let mut keys = vec![0u32; n];
                    let mut vals = vec![0.0f32; j1 - j0];
                    for j in j0..j1 {
                        let col = &scores_t[j * n..(j + 1) * n];
                        for i in 0..n {
                            keys[i] = f32_order_key(col[i] - p[i]);
                        }
                        vals[j - j0] =
                            kth_largest_keys(&mut keys, cc).max(0.0);
                    }
                    parts.lock().unwrap()[c] = Some(vals);
                };
                pool.scoped_run(col_chunks.len(), &job);
                let parts = parts.into_inner().unwrap();
                for (c, part) in parts.into_iter().enumerate() {
                    let (j0, j1) = col_chunks[c];
                    self.q[j0..j1]
                        .copy_from_slice(&part.expect("q chunk"));
                }
            }
        }
    }

    /// Bytes of persistent solver state: the duals plus every buffer
    /// retained between batches (column-major score copy + quickselect
    /// scratch) — the full O(n·m) footprint Algorithm 1 carries, which
    /// the serving report compares against Alg 3/4's bounded state.
    pub fn state_bytes(&self) -> usize {
        (self.q.len() + self.p.len() + self.scores_t.len()) * 4
            + (self.scratch_row.len() + self.scratch_col.len()) * 4
    }

    /// Route with the current duals: Topk(s_i - q, k) per token, gate
    /// weight = original score (Alg. 1 line 13).
    pub fn route(&self, inst: &Instance) -> Routing {
        let mut biased = vec![0.0f32; inst.m];
        let assignment = (0..inst.n)
            .map(|i| {
                let row = inst.row(i);
                for j in 0..inst.m {
                    biased[j] = row[j] - self.q[j];
                }
                topk_indices(&biased, inst.k)
                    .into_iter()
                    .map(|e| e as u32)
                    .collect()
            })
            .collect();
        Routing { assignment }
    }
}

/// Contiguous `[start, end)` ranges splitting `n` items into at most
/// `chunks` near-equal pieces (never empty, covers exactly `0..n`).
fn chunk_bounds(n: usize, chunks: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, n);
    let size = (n + chunks - 1) / chunks;
    (0..n)
        .step_by(size)
        .map(|a| (a, (a + size).min(n)))
        .collect()
}

/// One-shot convenience: T iterations from cold start, then route.
pub fn solve(inst: &Instance, t_iters: usize) -> (Routing, Vec<f32>) {
    let mut state = DualState::new(inst.m);
    state.update(inst, t_iters);
    let routing = state.route(inst);
    let q = state.q.clone();
    (routing, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bip::flow::solve_exact;
    use crate::bip::greedy_topk;
    use crate::util::rng::Pcg64;

    fn synth(seed: u64, n: usize, m: usize, k: usize, skew: f64) -> Instance {
        let mut rng = Pcg64::new(seed);
        Instance::synthetic(n, m, k, 2.0, skew, &mut rng)
    }

    #[test]
    fn duals_are_nonnegative() {
        let inst = synth(0, 128, 16, 4, 2.0);
        let (_, q) = solve(&inst, 8);
        assert!(q.iter().all(|&x| x >= 0.0));
        assert!(q.iter().any(|&x| x > 0.0)); // skew forces binding duals
    }

    #[test]
    fn balances_skewed_instances_in_one_shot() {
        for seed in 0..5 {
            let inst = synth(seed, 256, 16, 4, 3.0);
            let (routing, _) = solve(&inst, 8);
            let greedy = greedy_topk(&inst);
            assert!(routing.max_violation(&inst) <= 0.30,
                    "vio {}", routing.max_violation(&inst));
            assert!(routing.max_violation(&inst)
                    < greedy.max_violation(&inst));
        }
    }

    #[test]
    fn objective_close_to_exact_optimum() {
        // the paper's primal-dual argument: the heuristic's objective sits
        // within a few percent of the true (BIP) optimum
        for seed in [1u64, 2, 3] {
            let inst = synth(seed, 64, 8, 2, 2.0);
            let (exact_routing, exact_obj) = solve_exact(&inst);
            assert!(exact_routing.is_col_feasible(inst.m, inst.cap));
            let (routing, _) = solve(&inst, 14);
            let obj = routing.objective(&inst);
            assert!(obj >= 0.85 * exact_obj,
                    "obj {obj} exact {exact_obj}");
        }
    }

    #[test]
    fn loose_capacity_means_zero_duals_and_greedy_routing() {
        let mut inst = synth(4, 64, 8, 2, 2.0);
        inst.cap = inst.n; // constraint (2) can never bind
        let (routing, q) = solve(&inst, 8);
        assert!(q.iter().all(|&x| x == 0.0));
        let greedy = greedy_topk(&inst);
        assert_eq!(routing.assignment, greedy.assignment);
    }

    #[test]
    fn warm_start_transfers_across_batches() {
        // q learned on batches from a fixed skew distribution balances an
        // unseen batch better than cold-start with tiny T
        let mut state = DualState::new(16);
        for seed in 0..6 {
            let inst = synth(100 + seed, 256, 16, 4, 3.0);
            state.update(&inst, 2);
        }
        let fresh = synth(999, 256, 16, 4, 3.0);
        let warm_vio = state.route(&fresh).max_violation(&fresh);
        let cold_vio = greedy_topk(&fresh).max_violation(&fresh);
        assert!(warm_vio < cold_vio, "warm {warm_vio} cold {cold_vio}");
    }

    #[test]
    fn more_iterations_weakly_improve_balance() {
        let inst = synth(5, 256, 16, 4, 3.0);
        let vio_t1 = solve(&inst, 1).0.max_violation(&inst);
        let vio_t8 = solve(&inst, 8).0.max_violation(&inst);
        assert!(vio_t8 <= vio_t1 + 0.05, "t1 {vio_t1} t8 {vio_t8}");
    }

    #[test]
    fn chunk_bounds_partition_exactly() {
        for (n, c) in [(10usize, 3usize), (1, 4), (16, 16), (257, 4),
                       (5, 1), (0, 3)] {
            let bounds = chunk_bounds(n, c);
            let mut covered = 0;
            for (i, &(a, b)) in bounds.iter().enumerate() {
                assert!(a < b, "empty chunk n={n} c={c}");
                assert_eq!(a, covered, "gap n={n} c={c} chunk {i}");
                covered = b;
            }
            assert_eq!(covered, n);
            assert!(bounds.len() <= c.max(1));
        }
    }

    #[test]
    fn parallel_update_is_bit_identical_to_serial() {
        // the tentpole equivalence claim: chunked p/q phases produce
        // exactly the serial duals and routing, across seeds, T values,
        // warm-started multi-batch streams, and ragged sizes
        let pool = Pool::new(3);
        for seed in [0u64, 3, 11] {
            for t in [1usize, 2, 5] {
                let mut serial = DualState::new(16);
                let mut parallel = DualState::new(16);
                for b in 0..3 {
                    // 257 tokens: not divisible by the chunk count
                    let inst =
                        synth(1000 * seed + b, 257, 16, 4, 3.0);
                    serial.update(&inst, t);
                    parallel.update_parallel(&inst, t, &pool);
                    assert_eq!(serial.q, parallel.q,
                               "q diverged seed={seed} t={t} b={b}");
                    assert_eq!(serial.p, parallel.p,
                               "p diverged seed={seed} t={t} b={b}");
                    assert_eq!(
                        serial.route(&inst).assignment,
                        parallel.route(&inst).assignment,
                        "routing diverged seed={seed} t={t} b={b}"
                    );
                    assert_eq!(serial.state_bytes(),
                               parallel.state_bytes());
                }
            }
        }
        pool.join();
    }

    #[test]
    fn state_bytes_count_every_persistent_buffer() {
        let mut state = DualState::new(16);
        // before any batch: just q
        assert_eq!(state.state_bytes(), 16 * 4);
        let inst = synth(0, 128, 16, 4, 2.0);
        state.update(&inst, 2);
        // q + p + scores_t + row/col quickselect scratch, all 4-byte
        let expect = (16 + 128 + 128 * 16) * 4 + (16 + 128) * 4;
        assert_eq!(state.state_bytes(), expect);
    }

    #[test]
    fn row_feasibility_always_holds() {
        let inst = synth(6, 100, 10, 3, 1.0);
        let (routing, _) = solve(&inst, 4);
        assert!(routing.is_row_feasible(inst.k));
        assert_eq!(
            routing.assignment.iter().map(|a| a.len()).sum::<usize>(),
            inst.n * inst.k
        );
    }
}
