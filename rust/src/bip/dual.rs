//! Algorithm 1 (lines 7-12): the T-iteration primal-dual / ADMM update —
//! host-side mirror of the L1 Pallas kernel, same order statistics, same
//! tie-breaking, so the two implementations are interchangeable (verified
//! against the kernel through the artifact-equivalence integration test).
//!
//! Per iteration, with scratch buffers reused across calls:
//!   p_i = max(0, (k+1)-th largest of  s_i· - q)        [token duals]
//!   q_j = max(0, (cap+1)-th largest of s_·j - p)       [expert duals]
//!
//! Complexity: O(T · n · m) with quickselect (no sort), ~microseconds for
//! the paper's gate sizes — the "very small time costs" claim the solver
//! bench quantifies.
//!
//! All scratch lives in a [`ScoreArena`] (`perf::arena`): the serving
//! stack hands one shared arena down through every layer (`*_in`
//! variants), so the O(n·m) transpose + order-key buffers exist once
//! per router and the steady state allocates nothing; the plain
//! `update`/`update_parallel`/`update_adaptive` entry points fall back
//! to a private arena for standalone use (`solve`, benches, tests).
//!
//! Three solver modes:
//!   * [`DualState::update`] — the fixed-T path (bit-compatible with
//!     the kernel);
//!   * [`DualState::update_parallel`] — the same recurrence with the
//!     p-phase chunked over token rows and the q-phase over expert
//!     columns on a shared [`Pool`]. Each chunk stages its outputs in
//!     a cacheline-padded shard row of the arena (no two workers ever
//!     store to the same line) and a serial gather lands them in
//!     `p`/`q`; an order statistic over the same multiset is the same
//!     value regardless of partitioning, so the result is
//!     bit-identical to serial — pinned by the equivalence tests. The
//!     pre-sharding direct-write variant survives as
//!     [`DualState::update_parallel_shared_in`], the measured twin the
//!     kernel bench prices false sharing against;
//!   * [`DualState::update_adaptive`] — the convergence-adaptive path:
//!     early-exits when the duals go quiet AND the routed MaxVio has
//!     stopped improving, restores the best duals seen, and lazily
//!     re-evaluates converged expert columns only every other
//!     iteration. `tol = 0` disables every approximation and is
//!     bit-identical to the fixed-T path (serial and parallel).

use super::{Instance, Routing};
use crate::obs::event::{self, EventKind};
use crate::perf::block;
use crate::perf::{AssignmentBuf, ScoreArena};
use crate::prof::{Frame, ProfGuard};
use crate::telemetry;
use crate::util::pool::Pool;
use crate::util::stats::{
    f32_order_key, kth_largest_keys, topk_indices, topk_into,
};

/// Scale from the caller's MaxVio-level tolerance to the dual-delta
/// threshold the early exit checks: duals move on the softmax-score
/// scale, where steps ~100x smaller than a MaxVio step still shuffle
/// near-tie tokens (calibrated in python against f64 dynamics; see the
/// adaptive tests' margins).
const ADAPTIVE_TOL_TO_DELTA: f32 = 0.05;
/// Consecutive no-new-best primal evaluations before the exit arms.
const ADAPTIVE_PATIENCE: u32 = 3;
/// Consecutive exactly-unchanged iterations before a column goes lazy.
const ADAPTIVE_CALM_NEED: u32 = 2;
/// Lazy columns are re-evaluated every this many iterations.
const ADAPTIVE_RECHECK: usize = 2;

/// Raw-pointer capsule for handing disjoint chunk writes to pool jobs.
/// SAFETY: every user writes only its own pre-partitioned index range,
/// and `scoped_run` returns only after all jobs complete, so the
/// pointee outlives every access and no two jobs alias.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);

// SAFETY: a SendPtr is only dereferenced inside pool jobs that write
// pre-partitioned disjoint ranges; the pointee is owned by the caller
// of `scoped_run`, which blocks until every job has finished
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared references to a SendPtr only copy the raw pointer;
// all writes through it target disjoint per-chunk ranges (see Send)
unsafe impl<T> Sync for SendPtr<T> {}

/// Reusable solver state: the warm-started dual vector q (Alg. 1 line 2
/// initializes it once per gate, NOT once per batch) plus the token
/// duals p. Batch-shaped scratch lives in the arena.
#[derive(Clone, Debug)]
pub struct DualState {
    pub q: Vec<f32>,
    pub p: Vec<f32>,
    /// fallback arena for the standalone entry points; the serving
    /// stack passes its shared arena to the `*_in` variants and this
    /// stays empty
    arena: ScoreArena,
}

impl DualState {
    // COLD: cold-start construction (once per gate, never per batch);
    // the static hot-path lint stops here
    pub fn new(m: usize) -> Self {
        DualState {
            q: vec![0.0; m],
            p: Vec::new(),
            arena: ScoreArena::new(),
        }
    }

    /// Run `f` against this state's private fallback arena (every
    /// standalone/compat entry point funnels through here, so the
    /// take-and-restore dance exists once).
    pub fn with_fallback_arena<R>(
        &mut self,
        f: impl FnOnce(&mut DualState, &mut ScoreArena) -> R,
    ) -> R {
        let mut arena = std::mem::take(&mut self.arena);
        let out = f(self, &mut arena);
        self.arena = arena;
        out
    }

    /// Run T dual iterations against one batch's scores (Alg. 1 lines
    /// 7-12), using the private fallback arena.
    pub fn update(&mut self, inst: &Instance, t_iters: usize) {
        self.with_fallback_arena(|s, a| s.update_in(inst, t_iters, a));
    }

    /// [`DualState::update`] against a caller-owned arena — the serving
    /// stack's zero-allocation seam.
    // HOT: per-batch solver entry; no locks, no allocation
    pub fn update_in(
        &mut self,
        inst: &Instance,
        t_iters: usize,
        arena: &mut ScoreArena,
    ) {
        let _prof = ProfGuard::enter(Frame::DualUpdate);
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        arena.prepare_batch(n, m);
        // the router's fused fill-side transpose, when present for
        // exactly this batch shape, already holds scores_t
        if !arena.take_transpose(n, m) {
            transpose_serial(inst, &mut arena.scores_t);
        }
        for _ in 0..t_iters {
            {
                let _prof_p = ProfGuard::enter(Frame::DualP);
                p_phase_serial(
                    inst,
                    &self.q,
                    &mut self.p,
                    &mut arena.order_keys,
                    kk,
                );
            }
            let _prof_q = ProfGuard::enter(Frame::DualQ);
            q_phase_serial(
                n,
                m,
                &arena.scores_t,
                &self.p,
                &mut self.q,
                &mut arena.order_keys,
                cc,
                None,
                0,
            );
        }
    }

    /// Shared-pool variant of [`DualState::update`]: the p-phase is
    /// chunked over token rows and the q-phase over expert columns.
    /// Every chunk evaluates exactly the serial per-element recurrence
    /// into its own pre-partitioned slice of `p`/`q`/the key scratch,
    /// so `q`, `p` and the subsequent routing are bit-identical to the
    /// serial path — the equivalence tests pin this.
    pub fn update_parallel(
        &mut self,
        inst: &Instance,
        t_iters: usize,
        pool: &Pool,
    ) {
        self.with_fallback_arena(|s, a| {
            s.update_parallel_in(inst, t_iters, pool, a)
        });
    }

    /// [`DualState::update_parallel`] against a caller-owned arena.
    pub fn update_parallel_in(
        &mut self,
        inst: &Instance,
        t_iters: usize,
        pool: &Pool,
        arena: &mut ScoreArena,
    ) {
        if pool.threads() <= 1 {
            return self.update_in(inst, t_iters, arena);
        }
        let _prof = ProfGuard::enter(Frame::DualUpdate);
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        arena.prepare_batch(n, m);
        arena.prepare_shards(shard_floats(n, m, pool.threads()));
        if !arena.take_transpose(n, m) {
            transpose_parallel(inst, &mut arena.scores_t, pool);
        }
        for _ in 0..t_iters {
            {
                let _prof_p = ProfGuard::enter(Frame::DualP);
                p_phase_parallel(
                    inst,
                    &self.q,
                    &mut self.p,
                    &mut arena.order_keys,
                    kk,
                    pool,
                    &mut arena.shards,
                );
            }
            let _prof_q = ProfGuard::enter(Frame::DualQ);
            q_phase_parallel(
                n,
                m,
                &arena.scores_t,
                &self.p,
                &mut self.q,
                &mut arena.order_keys,
                cc,
                None,
                0,
                pool,
                &mut arena.shards,
            );
        }
    }

    /// Pre-sharding pool variant of [`DualState::update_parallel_in`]:
    /// chunks write their p/q outputs straight into interleaved regions
    /// of the shared vectors, so adjacent chunks' stores land on the
    /// same cachelines at every boundary (false sharing). Kept as the
    /// measured reference twin the kernel bench prices the padded
    /// shard staging against; bit-identical to the sharded default and
    /// to serial, which the equivalence tests pin.
    pub fn update_parallel_shared_in(
        &mut self,
        inst: &Instance,
        t_iters: usize,
        pool: &Pool,
        arena: &mut ScoreArena,
    ) {
        if pool.threads() <= 1 {
            return self.update_in(inst, t_iters, arena);
        }
        let _prof = ProfGuard::enter(Frame::DualUpdate);
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        arena.prepare_batch(n, m);
        if !arena.take_transpose(n, m) {
            transpose_parallel(inst, &mut arena.scores_t, pool);
        }
        for _ in 0..t_iters {
            {
                let _prof_p = ProfGuard::enter(Frame::DualP);
                p_phase_parallel_shared(
                    inst,
                    &self.q,
                    &mut self.p,
                    &mut arena.order_keys,
                    kk,
                    pool,
                );
            }
            let _prof_q = ProfGuard::enter(Frame::DualQ);
            q_phase_parallel_shared(
                n,
                m,
                &arena.scores_t,
                &self.p,
                &mut self.q,
                &mut arena.order_keys,
                cc,
                None,
                0,
                pool,
            );
        }
    }

    /// Convergence-adaptive Algorithm 1 (serial), using the private
    /// fallback arena. Returns the iterations actually run.
    ///
    /// Semantics (`tol > 0`):
    ///   * after every iteration the current duals are priced by a
    ///     primal evaluation (route + MaxVio, reusing arena scratch);
    ///     the best duals seen are snapshotted;
    ///   * the solver stops once `ADAPTIVE_PATIENCE` consecutive
    ///     evaluations fail to set a new best AND the max dual delta
    ///     over live columns is `<= tol * ADAPTIVE_TOL_TO_DELTA`; the
    ///     best snapshot is restored (also on t_max exhaustion);
    ///   * an expert column whose dual was *exactly* unchanged for
    ///     `ADAPTIVE_CALM_NEED` consecutive live iterations goes lazy:
    ///     it is only re-evaluated every `ADAPTIVE_RECHECK`-th
    ///     iteration (and wakes back up the moment a recheck moves it)
    ///     — the "prune converged columns" part of the q-phase.
    ///
    /// With `tol = 0` every approximation is disabled and the loop
    /// early-exits only at an *exact* fixpoint (`Δq == 0`), after which
    /// further fixed iterations would recompute identical p and q — so
    /// the result is bit-identical to `update(inst, t_max)`, serial and
    /// parallel, which the pinning tests assert.
    ///
    /// `p` reflects the final iteration run, not the restored best-q
    /// snapshot (only `q` feeds routing).
    pub fn update_adaptive(
        &mut self,
        inst: &Instance,
        t_max: usize,
        tol: f32,
    ) -> usize {
        self.with_fallback_arena(|s, a| {
            s.update_adaptive_in(inst, t_max, tol, a)
        })
    }

    /// [`DualState::update_adaptive`] against a caller-owned arena.
    // HOT: per-batch adaptive solver entry; no locks, no allocation
    pub fn update_adaptive_in(
        &mut self,
        inst: &Instance,
        t_max: usize,
        tol: f32,
        arena: &mut ScoreArena,
    ) -> usize {
        self.adaptive_core(inst, t_max, tol, arena, None)
    }

    /// Pool-chunked [`DualState::update_adaptive`] on the private
    /// fallback arena (standalone / compat callers).
    pub fn update_adaptive_parallel(
        &mut self,
        inst: &Instance,
        t_max: usize,
        tol: f32,
        pool: &Pool,
    ) -> usize {
        self.with_fallback_arena(|s, a| {
            s.update_adaptive_parallel_in(inst, t_max, tol, pool, a)
        })
    }

    /// Pool-chunked adaptive update: phases run like
    /// [`DualState::update_parallel_in`], all convergence decisions are
    /// taken serially from bit-identical phase outputs — so the
    /// adaptive path is itself bit-identical serial vs parallel.
    pub fn update_adaptive_parallel_in(
        &mut self,
        inst: &Instance,
        t_max: usize,
        tol: f32,
        pool: &Pool,
        arena: &mut ScoreArena,
    ) -> usize {
        if pool.threads() <= 1 {
            return self.adaptive_core(inst, t_max, tol, arena, None);
        }
        self.adaptive_core(inst, t_max, tol, arena, Some(pool))
    }

    fn adaptive_core(
        &mut self,
        inst: &Instance,
        t_max: usize,
        tol: f32,
        arena: &mut ScoreArena,
        pool: Option<&Pool>,
    ) -> usize {
        let _prof = ProfGuard::enter(Frame::DualUpdate);
        let (n, m, k, cap) = (inst.n, inst.m, inst.k, inst.cap);
        let kk = (k + 1).min(m);
        let cc = (cap + 1).min(n);
        self.p.resize(n, 0.0);
        arena.prepare_batch(n, m);
        arena.prepare_adaptive(m, k);
        arena.prepare_gate(m);
        if let Some(pool) = pool {
            arena.prepare_shards(shard_floats(n, m, pool.threads()));
        }
        if !arena.take_transpose(n, m) {
            match pool {
                Some(pool) => {
                    transpose_parallel(inst, &mut arena.scores_t, pool)
                }
                None => transpose_serial(inst, &mut arena.scores_t),
            }
        }
        let eps = tol * ADAPTIVE_TOL_TO_DELTA;
        let mut best_vio = f64::INFINITY;
        let mut stale = 0u32;
        arena.best_q[..m].copy_from_slice(&self.q);
        let mut iters = 0usize;
        let mut exit_reason = event::DUAL_EXIT_CAPPED;
        for t in 0..t_max {
            iters += 1;
            arena.prev_q[..m].copy_from_slice(&self.q);
            match pool {
                Some(pool) => {
                    {
                        let _prof_p = ProfGuard::enter(Frame::DualP);
                        p_phase_parallel(
                            inst,
                            &self.q,
                            &mut self.p,
                            &mut arena.order_keys,
                            kk,
                            pool,
                            &mut arena.shards,
                        );
                    }
                    let _prof_q = ProfGuard::enter(Frame::DualQ);
                    q_phase_parallel(
                        n,
                        m,
                        &arena.scores_t,
                        &self.p,
                        &mut self.q,
                        &mut arena.order_keys,
                        cc,
                        (tol > 0.0).then_some(arena.calm.as_slice()),
                        t,
                        pool,
                        &mut arena.shards,
                    );
                }
                None => {
                    {
                        let _prof_p = ProfGuard::enter(Frame::DualP);
                        p_phase_serial(
                            inst,
                            &self.q,
                            &mut self.p,
                            &mut arena.order_keys,
                            kk,
                        );
                    }
                    let _prof_q = ProfGuard::enter(Frame::DualQ);
                    q_phase_serial(
                        n,
                        m,
                        &arena.scores_t,
                        &self.p,
                        &mut self.q,
                        &mut arena.order_keys,
                        cc,
                        (tol > 0.0).then_some(arena.calm.as_slice()),
                        t,
                    );
                }
            }
            // delta + calm bookkeeping over live columns (serial: the
            // decisions must not depend on the chunking)
            let mut max_delta = 0.0f32;
            for j in 0..m {
                let live = !(tol > 0.0
                    && arena.calm[j] >= ADAPTIVE_CALM_NEED
                    && t % ADAPTIVE_RECHECK != 0);
                if !live {
                    continue;
                }
                let d = (self.q[j] - arena.prev_q[j]).abs();
                if d > max_delta {
                    max_delta = d;
                }
                arena.calm[j] =
                    if d == 0.0 { arena.calm[j] + 1 } else { 0 };
            }
            if tol <= 0.0 {
                // exact fixpoint: every further iteration is a no-op,
                // so stopping here is bit-identical to running them
                if max_delta == 0.0 {
                    exit_reason = event::DUAL_EXIT_FIXPOINT;
                    break;
                }
                continue;
            }
            let vio = eval_max_vio(
                inst,
                &self.q,
                &mut arena.biased,
                &mut arena.topk_idx,
                &mut arena.topk_out,
                &mut arena.loads_scratch,
            );
            // per-iteration MaxVio trajectory (preallocated atomics:
            // the adaptive solve stays allocation-free)
            telemetry::hist_observe(telemetry::Hist::SolverMaxVio, vio);
            if vio < best_vio {
                best_vio = vio;
                arena.best_q[..m].copy_from_slice(&self.q);
                stale = 0;
            } else {
                stale += 1;
            }
            if stale >= ADAPTIVE_PATIENCE && max_delta <= eps {
                exit_reason = event::DUAL_EXIT_CONVERGED;
                break;
            }
        }
        event::record_ctx_event(
            EventKind::DualExit,
            event::dual_exit_payload(exit_reason, iters),
        );
        if tol > 0.0 && best_vio.is_finite() {
            self.q.copy_from_slice(&arena.best_q[..m]);
            telemetry::gauge_set(
                telemetry::Gauge::SolverLastMaxVio,
                best_vio,
            );
            let calm = arena.calm[..m]
                .iter()
                .filter(|&&c| c >= ADAPTIVE_CALM_NEED)
                .count();
            telemetry::counter_add(
                telemetry::Counter::SolverCalmColumns,
                calm as u64,
            );
        }
        iters
    }

    /// Bytes of persistent solver state: the duals plus every buffer
    /// the fallback arena retains between batches (column-major score
    /// copy + quickselect scratch) — the full O(n·m) footprint
    /// Algorithm 1 carries when it runs standalone. On the serving
    /// path the shared arena is counted once at the router level
    /// instead (`ServingRouter::state_bytes`), not per layer.
    pub fn state_bytes(&self) -> usize {
        (self.q.len() + self.p.len()) * 4 + self.arena.state_bytes()
    }

    /// Route with the current duals: Topk(s_i - q, k) per token, gate
    /// weight = original score (Alg. 1 line 13).
    // COLD: allocating compat seam — serving routes through
    // `route_into`; the static hot-path lint stops here
    pub fn route(&self, inst: &Instance) -> Routing {
        let mut biased = vec![0.0f32; inst.m];
        let assignment = (0..inst.n)
            .map(|i| {
                let row = inst.row(i);
                for j in 0..inst.m {
                    biased[j] = row[j] - self.q[j];
                }
                topk_indices(&biased, inst.k)
                    .into_iter()
                    .map(|e| e as u32)
                    .collect()
            })
            .collect();
        Routing { assignment }
    }

    /// Allocation-free [`DualState::route`]: same decisions (the
    /// biased-score top-k has a total order), written into the reusable
    /// assignment buffer via arena scratch.
    // HOT: per-batch routing; no locks, no allocation
    pub fn route_into(
        &self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        arena.prepare_gate(inst.m);
        out.reset(inst.n, inst.k);
        for i in 0..inst.n {
            let row = inst.row(i);
            for j in 0..inst.m {
                arena.biased[j] = row[j] - self.q[j];
            }
            let len = topk_into(
                &arena.biased,
                inst.k,
                &mut arena.topk_idx,
                out.row_mut(i),
            );
            out.set_len(i, len);
        }
    }
}

/// Primal pricing of a dual vector: MaxVio of Topk(s - q) routing,
/// entirely on arena scratch (the adaptive solver calls this once per
/// iteration).
// HOT: runs once per adaptive iteration; no locks, no allocation
fn eval_max_vio(
    inst: &Instance,
    q: &[f32],
    biased: &mut Vec<f32>,
    topk_idx: &mut Vec<u32>,
    topk_out: &mut Vec<u32>,
    loads: &mut Vec<u32>,
) -> f64 {
    let (n, m, k) = (inst.n, inst.m, inst.k);
    biased.resize(m, 0.0);
    topk_idx.resize(m, 0);
    topk_out.resize(k, 0);
    loads.resize(m, 0);
    loads.iter_mut().for_each(|x| *x = 0);
    for i in 0..n {
        let row = inst.row(i);
        for j in 0..m {
            biased[j] = row[j] - q[j];
        }
        let len = topk_into(biased, k, topk_idx, topk_out);
        for &e in &topk_out[..len] {
            loads[e as usize] += 1;
        }
    }
    let mean = n as f64 * k as f64 / m as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    *loads.iter().max().unwrap_or(&0) as f64 / mean - 1.0
}

fn transpose_serial(inst: &Instance, scores_t: &mut [f32]) {
    let _prof = ProfGuard::enter(Frame::Transpose);
    block::transpose_into(&inst.scores, inst.n, inst.m, scores_t);
}

fn transpose_parallel(
    inst: &Instance,
    scores_t: &mut [f32],
    pool: &Pool,
) {
    let _prof = ProfGuard::enter(Frame::Transpose);
    let (n, m) = (inst.n, inst.m);
    let chunks = chunk_count(m, pool.threads());
    let t_ptr = SendPtr(scores_t.as_mut_ptr());
    let job = |c: usize| {
        let (j0, j1) = chunk_range(m, chunks, c);
        // SAFETY: output columns j0..j1 occupy the contiguous range
        // [j0*n, j1*n) of scores_t — disjoint per chunk — and
        // scores_t outlives scoped_run
        let dst = unsafe {
            std::slice::from_raw_parts_mut(
                t_ptr.0.add(j0 * n),
                (j1 - j0) * n,
            )
        };
        block::transpose_cols_into(&inst.scores, n, m, j0, j1, dst);
    };
    pool.scoped_run(chunks, &job);
}

// HOT: per-iteration token pricing; no locks, no allocation
fn p_phase_serial(
    inst: &Instance,
    q: &[f32],
    p: &mut [f32],
    keys: &mut [u32],
    kk: usize,
) {
    let m = inst.m;
    for i in 0..inst.n {
        let row = inst.row(i);
        let krow = &mut keys[i * m..(i + 1) * m];
        for j in 0..m {
            krow[j] = f32_order_key(row[j] - q[j]);
        }
        p[i] = kth_largest_keys(krow, kk).max(0.0);
    }
}

/// Pool-chunked p-phase, shard-staged: each chunk writes its token
/// duals into its own cacheline-padded shard row (so no two workers
/// ever store to the same line) and a serial gather copies the rows
/// into `p`. The staged values are the serial recurrence verbatim, so
/// `p` is bit-identical to [`p_phase_serial`].
fn p_phase_parallel(
    inst: &Instance,
    q: &[f32],
    p: &mut [f32],
    keys: &mut [u32],
    kk: usize,
    pool: &Pool,
    shards: &mut [f32],
) {
    let (n, m) = (inst.n, inst.m);
    let chunks = chunk_count(n, pool.threads());
    let stride = shard_stride(n, chunks);
    let k_ptr = SendPtr(keys.as_mut_ptr());
    let s_ptr = SendPtr(shards.as_mut_ptr());
    let job = |c: usize| {
        let (i0, i1) = chunk_range(n, chunks, c);
        // SAFETY: shard row c is the range [c*stride, c*stride+(i1-i0))
        // — strides are cacheline-rounded chunk sizes, so rows are
        // disjoint — and shards outlives scoped_run
        let srow = unsafe {
            std::slice::from_raw_parts_mut(
                s_ptr.0.add(c * stride),
                i1 - i0,
            )
        };
        for i in i0..i1 {
            let row = inst.row(i);
            // SAFETY: row ranges [i0, i1) are disjoint per chunk, and
            // key row i belongs to exactly one row chunk
            let krow = unsafe {
                std::slice::from_raw_parts_mut(k_ptr.0.add(i * m), m)
            };
            for j in 0..m {
                krow[j] = f32_order_key(row[j] - q[j]);
            }
            srow[i - i0] = kth_largest_keys(krow, kk).max(0.0);
        }
    };
    pool.scoped_run(chunks, &job);
    for c in 0..chunks {
        let (i0, i1) = chunk_range(n, chunks, c);
        p[i0..i1].copy_from_slice(
            &shards[c * stride..c * stride + (i1 - i0)],
        );
    }
}

/// Pre-sharding p-phase twin: chunks write `p` directly through
/// interleaved pointers (false sharing at every chunk boundary). Kept
/// only so the kernel bench can price the shard staging; bit-identical
/// to [`p_phase_parallel`].
fn p_phase_parallel_shared(
    inst: &Instance,
    q: &[f32],
    p: &mut [f32],
    keys: &mut [u32],
    kk: usize,
    pool: &Pool,
) {
    let (n, m) = (inst.n, inst.m);
    let chunks = chunk_count(n, pool.threads());
    let p_ptr = SendPtr(p.as_mut_ptr());
    let k_ptr = SendPtr(keys.as_mut_ptr());
    let job = |c: usize| {
        let (i0, i1) = chunk_range(n, chunks, c);
        for i in i0..i1 {
            let row = inst.row(i);
            // SAFETY: row ranges [i0, i1) are disjoint per chunk, and
            // key row i belongs to exactly one row chunk
            let krow = unsafe {
                std::slice::from_raw_parts_mut(k_ptr.0.add(i * m), m)
            };
            for j in 0..m {
                krow[j] = f32_order_key(row[j] - q[j]);
            }
            // SAFETY: p[i] is written by exactly one chunk (the one
            // owning row i) and p outlives scoped_run
            unsafe {
                *p_ptr.0.add(i) = kth_largest_keys(krow, kk).max(0.0)
            };
        }
    };
    pool.scoped_run(chunks, &job);
}

/// Whether an expert column sits out this iteration of the q-phase
/// (adaptive pruning): calm for long enough, and not a recheck turn.
#[inline]
fn column_is_lazy(calm: Option<&[u32]>, j: usize, t: usize) -> bool {
    match calm {
        Some(calm) => {
            calm[j] >= ADAPTIVE_CALM_NEED && t % ADAPTIVE_RECHECK != 0
        }
        None => false,
    }
}

// HOT: per-iteration expert pricing; no locks, no allocation
#[allow(clippy::too_many_arguments)]
fn q_phase_serial(
    n: usize,
    m: usize,
    scores_t: &[f32],
    p: &[f32],
    q: &mut [f32],
    keys: &mut [u32],
    cc: usize,
    calm: Option<&[u32]>,
    t: usize,
) {
    for j in 0..m {
        if column_is_lazy(calm, j, t) {
            continue;
        }
        let col = &scores_t[j * n..(j + 1) * n];
        let kcol = &mut keys[j * n..(j + 1) * n];
        for i in 0..n {
            kcol[i] = f32_order_key(col[i] - p[i]);
        }
        q[j] = kth_largest_keys(kcol, cc).max(0.0);
    }
}

/// Pool-chunked q-phase, shard-staged like [`p_phase_parallel`]: each
/// chunk prices its expert columns into its own padded shard row and a
/// serial gather lands them in `q`. Lazy (calm) columns are skipped in
/// both the worker job and the gather, so they keep their previous
/// dual exactly like the serial phase.
#[allow(clippy::too_many_arguments)]
fn q_phase_parallel(
    n: usize,
    m: usize,
    scores_t: &[f32],
    p: &[f32],
    q: &mut [f32],
    keys: &mut [u32],
    cc: usize,
    calm: Option<&[u32]>,
    t: usize,
    pool: &Pool,
    shards: &mut [f32],
) {
    let chunks = chunk_count(m, pool.threads());
    let stride = shard_stride(m, chunks);
    let k_ptr = SendPtr(keys.as_mut_ptr());
    let s_ptr = SendPtr(shards.as_mut_ptr());
    let job = |c: usize| {
        let (j0, j1) = chunk_range(m, chunks, c);
        // SAFETY: shard row c is the range [c*stride, c*stride+(j1-j0))
        // — strides are cacheline-rounded chunk sizes, so rows are
        // disjoint — and shards outlives scoped_run
        let srow = unsafe {
            std::slice::from_raw_parts_mut(
                s_ptr.0.add(c * stride),
                j1 - j0,
            )
        };
        for j in j0..j1 {
            if column_is_lazy(calm, j, t) {
                continue;
            }
            let col = &scores_t[j * n..(j + 1) * n];
            // SAFETY: column ranges [j0, j1) are disjoint per chunk
            let kcol = unsafe {
                std::slice::from_raw_parts_mut(k_ptr.0.add(j * n), n)
            };
            for i in 0..n {
                kcol[i] = f32_order_key(col[i] - p[i]);
            }
            srow[j - j0] = kth_largest_keys(kcol, cc).max(0.0);
        }
    };
    pool.scoped_run(chunks, &job);
    for c in 0..chunks {
        let (j0, j1) = chunk_range(m, chunks, c);
        for j in j0..j1 {
            if column_is_lazy(calm, j, t) {
                continue;
            }
            q[j] = shards[c * stride + (j - j0)];
        }
    }
}

/// Pre-sharding q-phase twin of [`q_phase_parallel`] (direct
/// interleaved writes into `q`); kept for the kernel bench.
#[allow(clippy::too_many_arguments)]
fn q_phase_parallel_shared(
    n: usize,
    m: usize,
    scores_t: &[f32],
    p: &[f32],
    q: &mut [f32],
    keys: &mut [u32],
    cc: usize,
    calm: Option<&[u32]>,
    t: usize,
    pool: &Pool,
) {
    let chunks = chunk_count(m, pool.threads());
    let q_ptr = SendPtr(q.as_mut_ptr());
    let k_ptr = SendPtr(keys.as_mut_ptr());
    let job = |c: usize| {
        let (j0, j1) = chunk_range(m, chunks, c);
        for j in j0..j1 {
            if column_is_lazy(calm, j, t) {
                continue;
            }
            let col = &scores_t[j * n..(j + 1) * n];
            // SAFETY: column ranges [j0, j1) are disjoint per chunk
            let kcol = unsafe {
                std::slice::from_raw_parts_mut(k_ptr.0.add(j * n), n)
            };
            for i in 0..n {
                kcol[i] = f32_order_key(col[i] - p[i]);
            }
            // SAFETY: q[j] is written by exactly one chunk (the one
            // owning column j) and q outlives scoped_run
            unsafe {
                *q_ptr.0.add(j) = kth_largest_keys(kcol, cc).max(0.0)
            };
        }
    };
    pool.scoped_run(chunks, &job);
}

/// Floats per 64-byte cacheline — the shard-stride rounding unit.
const SHARD_LINE: usize = 16;

/// Padded per-chunk stride (in floats) for staging `len` outputs
/// across `chunks` workers: the chunk size rounded up to a whole
/// cacheline, so adjacent workers never store to the same line.
fn shard_stride(len: usize, chunks: usize) -> usize {
    let size = (len + chunks - 1) / chunks;
    (size + SHARD_LINE - 1) / SHARD_LINE * SHARD_LINE
}

/// Shard-staging floats the pool-parallel dual update needs for an
/// `(n, m)` batch on `threads` workers: the larger of the p-phase
/// (token rows) and q-phase (expert columns) geometries. Public so the
/// state-accounting tests and the kernel bench can predict the arena
/// growth exactly.
pub fn shard_floats(n: usize, m: usize, threads: usize) -> usize {
    let pc = chunk_count(n, threads);
    let qc = chunk_count(m, threads);
    let p_need = if pc == 0 { 0 } else { pc * shard_stride(n, pc) };
    let q_need = if qc == 0 { 0 } else { qc * shard_stride(m, qc) };
    p_need.max(q_need)
}

/// How many chunks [`chunk_range`] splits `n` items into for `threads`
/// workers (same arithmetic as [`chunk_bounds`], allocation-free).
pub(crate) fn chunk_count(n: usize, threads: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let chunks = threads.clamp(1, n);
    let size = (n + chunks - 1) / chunks;
    (n + size - 1) / size
}

/// The `c`-th contiguous `[start, end)` range of `n` items split into
/// `chunks` near-equal pieces (never empty, covers exactly `0..n` —
/// pinned against [`chunk_bounds`] by the tests).
pub(crate) fn chunk_range(
    n: usize,
    chunks: usize,
    c: usize,
) -> (usize, usize) {
    let size = (n + chunks - 1) / chunks;
    let a = c * size;
    (a, (a + size).min(n))
}

/// Contiguous `[start, end)` ranges splitting `n` items into at most
/// `chunks` near-equal pieces (never empty, covers exactly `0..n`).
/// Kept as the allocating reference for [`chunk_range`]; the hot path
/// computes ranges arithmetically instead.
#[cfg(test)]
fn chunk_bounds(n: usize, chunks: usize) -> Vec<(usize, usize)> {
    let count = chunk_count(n, chunks);
    (0..count).map(|c| chunk_range(n, count, c)).collect()
}

/// One-shot convenience: T iterations from cold start, then route.
pub fn solve(inst: &Instance, t_iters: usize) -> (Routing, Vec<f32>) {
    let mut state = DualState::new(inst.m);
    state.update(inst, t_iters);
    let routing = state.route(inst);
    let q = state.q.clone();
    (routing, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bip::flow::solve_exact;
    use crate::bip::greedy_topk;
    use crate::util::rng::Pcg64;

    fn synth(seed: u64, n: usize, m: usize, k: usize, skew: f64) -> Instance {
        let mut rng = Pcg64::new(seed);
        Instance::synthetic(n, m, k, 2.0, skew, &mut rng)
    }

    #[test]
    fn duals_are_nonnegative() {
        let inst = synth(0, 128, 16, 4, 2.0);
        let (_, q) = solve(&inst, 8);
        assert!(q.iter().all(|&x| x >= 0.0));
        assert!(q.iter().any(|&x| x > 0.0)); // skew forces binding duals
    }

    #[test]
    fn balances_skewed_instances_in_one_shot() {
        for seed in 0..5 {
            let inst = synth(seed, 256, 16, 4, 3.0);
            let (routing, _) = solve(&inst, 8);
            let greedy = greedy_topk(&inst);
            assert!(routing.max_violation(&inst) <= 0.30,
                    "vio {}", routing.max_violation(&inst));
            assert!(routing.max_violation(&inst)
                    < greedy.max_violation(&inst));
        }
    }

    #[test]
    fn objective_close_to_exact_optimum() {
        // the paper's primal-dual argument: the heuristic's objective sits
        // within a few percent of the true (BIP) optimum
        for seed in [1u64, 2, 3] {
            let inst = synth(seed, 64, 8, 2, 2.0);
            let (exact_routing, exact_obj) = solve_exact(&inst);
            assert!(exact_routing.is_col_feasible(inst.m, inst.cap));
            let (routing, _) = solve(&inst, 14);
            let obj = routing.objective(&inst);
            assert!(obj >= 0.85 * exact_obj,
                    "obj {obj} exact {exact_obj}");
        }
    }

    #[test]
    fn loose_capacity_means_zero_duals_and_greedy_routing() {
        let mut inst = synth(4, 64, 8, 2, 2.0);
        inst.cap = inst.n; // constraint (2) can never bind
        let (routing, q) = solve(&inst, 8);
        assert!(q.iter().all(|&x| x == 0.0));
        let greedy = greedy_topk(&inst);
        assert_eq!(routing.assignment, greedy.assignment);
    }

    #[test]
    fn warm_start_transfers_across_batches() {
        // q learned on batches from a fixed skew distribution balances an
        // unseen batch better than cold-start with tiny T
        let mut state = DualState::new(16);
        for seed in 0..6 {
            let inst = synth(100 + seed, 256, 16, 4, 3.0);
            state.update(&inst, 2);
        }
        let fresh = synth(999, 256, 16, 4, 3.0);
        let warm_vio = state.route(&fresh).max_violation(&fresh);
        let cold_vio = greedy_topk(&fresh).max_violation(&fresh);
        assert!(warm_vio < cold_vio, "warm {warm_vio} cold {cold_vio}");
    }

    #[test]
    fn more_iterations_weakly_improve_balance() {
        let inst = synth(5, 256, 16, 4, 3.0);
        let vio_t1 = solve(&inst, 1).0.max_violation(&inst);
        let vio_t8 = solve(&inst, 8).0.max_violation(&inst);
        assert!(vio_t8 <= vio_t1 + 0.05, "t1 {vio_t1} t8 {vio_t8}");
    }

    #[test]
    fn chunk_bounds_partition_exactly() {
        for (n, c) in [(10usize, 3usize), (1, 4), (16, 16), (257, 4),
                       (5, 1), (0, 3)] {
            let bounds = chunk_bounds(n, c);
            let mut covered = 0;
            for (i, &(a, b)) in bounds.iter().enumerate() {
                assert!(a < b, "empty chunk n={n} c={c}");
                assert_eq!(a, covered, "gap n={n} c={c} chunk {i}");
                covered = b;
            }
            assert_eq!(covered, n);
            assert!(bounds.len() <= c.max(1));
        }
    }

    #[test]
    fn chunk_range_agrees_with_chunk_bounds() {
        // the no-alloc path computes ranges arithmetically; it must
        // reproduce the allocating reference exactly
        for (n, threads) in [(10usize, 3usize), (1, 4), (16, 16),
                             (257, 4), (5, 1), (64, 5), (63, 8)] {
            let bounds = chunk_bounds(n, threads);
            let count = chunk_count(n, threads);
            assert_eq!(bounds.len(), count, "n={n} threads={threads}");
            for (c, &want) in bounds.iter().enumerate() {
                assert_eq!(chunk_range(n, count, c), want,
                           "n={n} threads={threads} chunk {c}");
            }
        }
        assert_eq!(chunk_count(0, 3), 0);
    }

    #[test]
    fn parallel_update_is_bit_identical_to_serial() {
        // the tentpole equivalence claim: chunked p/q phases produce
        // exactly the serial duals and routing, across seeds, T values,
        // warm-started multi-batch streams, and ragged sizes
        let pool = Pool::new(3);
        for seed in [0u64, 3, 11] {
            for t in [1usize, 2, 5] {
                let mut serial = DualState::new(16);
                let mut parallel = DualState::new(16);
                for b in 0..3 {
                    // 257 tokens: not divisible by the chunk count
                    let inst =
                        synth(1000 * seed + b, 257, 16, 4, 3.0);
                    serial.update(&inst, t);
                    parallel.update_parallel(&inst, t, &pool);
                    assert_eq!(serial.q, parallel.q,
                               "q diverged seed={seed} t={t} b={b}");
                    assert_eq!(serial.p, parallel.p,
                               "p diverged seed={seed} t={t} b={b}");
                    assert_eq!(
                        serial.route(&inst).assignment,
                        parallel.route(&inst).assignment,
                        "routing diverged seed={seed} t={t} b={b}"
                    );
                    // accounted footprint is path-independent: the
                    // shard staging exists on the parallel side but is
                    // deliberately outside state_bytes
                    assert_eq!(serial.state_bytes(),
                               parallel.state_bytes());
                    assert!(serial.arena.shards.is_empty());
                    assert_eq!(parallel.arena.shards.len(),
                               shard_floats(257, 16, 3));
                }
            }
        }
        pool.join();
    }

    #[test]
    fn sharded_update_matches_the_shared_write_twin() {
        // the bench twin must stay bit-identical to the sharded
        // default, or the false-sharing comparison prices two
        // different computations
        let pool = Pool::new(3);
        let mut sharded = DualState::new(16);
        let mut shared = DualState::new(16);
        let mut sharded_arena = ScoreArena::new();
        let mut shared_arena = ScoreArena::new();
        for b in 0..3 {
            let inst = synth(77 + b, 257, 16, 4, 3.0);
            sharded.update_parallel_in(
                &inst, 3, &pool, &mut sharded_arena,
            );
            shared.update_parallel_shared_in(
                &inst, 3, &pool, &mut shared_arena,
            );
            assert_eq!(sharded.q, shared.q, "q diverged b={b}");
            assert_eq!(sharded.p, shared.p, "p diverged b={b}");
        }
        pool.join();
    }

    #[test]
    fn shard_geometry_pads_to_whole_cachelines() {
        // every stride is a cacheline multiple covering its chunk
        for (len, threads) in [(257usize, 3usize), (16, 3), (1, 4),
                               (64, 5), (4096, 8)] {
            let chunks = chunk_count(len, threads);
            let stride = shard_stride(len, chunks);
            assert_eq!(stride % SHARD_LINE, 0, "len={len}");
            let (a, b) = chunk_range(len, chunks, 0);
            assert!(stride >= b - a, "len={len} threads={threads}");
        }
        // worked example the routing/router tests rely on:
        // ceil(257/3) = 86 -> 96 padded, 3 chunks; q side 3 * 16
        assert_eq!(shard_floats(257, 16, 3), 3 * 96);
        assert_eq!(shard_floats(256, 16, 3), 3 * 96);
        assert_eq!(shard_floats(0, 0, 3), 0);
    }

    #[test]
    fn state_bytes_count_every_persistent_buffer() {
        let mut state = DualState::new(16);
        // before any batch: just q (p and the fallback arena are empty)
        assert_eq!(state.state_bytes(), 16 * 4);
        let inst = synth(0, 128, 16, 4, 2.0);
        state.update(&inst, 2);
        // q + p, plus the fallback arena's batch scratch: the (m, n)
        // transpose and the n*m order-key buffer, all 4-byte. Any newly
        // added DualState or batch-scratch field must be counted in
        // state_bytes AND here, or this exact equality fails.
        let expect = (16 + 128) * 4 + 2 * (128 * 16) * 4;
        assert_eq!(state.state_bytes(), expect);

        // the serving seam leaves the fallback arena untouched: a
        // state driven via update_in reports only its own q + p, and
        // the shared arena is accounted once by the router
        let mut shared = ScoreArena::new();
        let mut lean = DualState::new(16);
        lean.update_in(&inst, 2, &mut shared);
        assert_eq!(lean.state_bytes(), (16 + 128) * 4);
        assert_eq!(shared.state_bytes(), 2 * (128 * 16) * 4);
        assert_eq!(lean.q, state.q);
        assert_eq!(lean.p, state.p);
    }

    #[test]
    fn row_feasibility_always_holds() {
        let inst = synth(6, 100, 10, 3, 1.0);
        let (routing, _) = solve(&inst, 4);
        assert!(routing.is_row_feasible(inst.k));
        assert_eq!(
            routing.assignment.iter().map(|a| a.len()).sum::<usize>(),
            inst.n * inst.k
        );
    }

    #[test]
    fn route_into_matches_route() {
        let mut state = DualState::new(16);
        let inst = synth(9, 128, 16, 4, 3.0);
        state.update(&inst, 4);
        let mut arena = ScoreArena::new();
        let mut buf = AssignmentBuf::new();
        state.route_into(&inst, &mut arena, &mut buf);
        assert_eq!(
            buf.to_routing().assignment,
            state.route(&inst).assignment
        );
    }

    #[test]
    fn adaptive_tol_zero_is_bit_identical_to_fixed_t() {
        // the tentpole pinning claim, serial and pooled, across seeded
        // skewed/uniform instances and warm-started streams
        let pool = Pool::new(3);
        for seed in [0u64, 7, 21] {
            for skew in [0.0, 3.0] {
                for t_max in [1usize, 4, 24] {
                    let mut fixed = DualState::new(16);
                    let mut adapt = DualState::new(16);
                    let mut padapt = DualState::new(16);
                    for b in 0..3 {
                        let inst = synth(
                            7000 + 100 * seed + b,
                            257,
                            16,
                            4,
                            skew,
                        );
                        fixed.update(&inst, t_max);
                        let iters =
                            adapt.update_adaptive(&inst, t_max, 0.0);
                        let mut arena = ScoreArena::new();
                        let piters = padapt.update_adaptive_parallel_in(
                            &inst, t_max, 0.0, &pool, &mut arena,
                        );
                        assert!(iters <= t_max && iters >= 1.min(t_max));
                        assert_eq!(iters, piters,
                                   "iter count diverged seed={seed}");
                        assert_eq!(fixed.q, adapt.q,
                                   "q seed={seed} skew={skew} t={t_max}");
                        assert_eq!(fixed.p, adapt.p,
                                   "p seed={seed} skew={skew} t={t_max}");
                        assert_eq!(fixed.q, padapt.q,
                                   "pooled q seed={seed} t={t_max}");
                        assert_eq!(fixed.p, padapt.p,
                                   "pooled p seed={seed} t={t_max}");
                        assert_eq!(
                            fixed.route(&inst).assignment,
                            adapt.route(&inst).assignment
                        );
                    }
                }
            }
        }
        pool.join();
    }

    #[test]
    fn adaptive_tolerance_bounds_the_maxvio_gap() {
        // python-validated margins (3.2x-25x headroom over 30 seeds):
        // the adaptive solver never lands more than tol above the
        // fixed-T MaxVio on the paper's gate sizes, while saving a
        // large share of the iterations
        let t_max = 16usize;
        for (n, tol) in [(1024usize, 0.05f32), (1024, 0.1), (256, 0.1)] {
            for skew in [0.0, 3.0] {
                for seed in [0u64, 1, 2, 3] {
                    let mut fixed = DualState::new(16);
                    let mut adapt = DualState::new(16);
                    let mut total_iters = 0usize;
                    for b in 0..4 {
                        let inst = synth(
                            9000 + 100 * seed + b,
                            n,
                            16,
                            4,
                            skew,
                        );
                        fixed.update(&inst, t_max);
                        total_iters +=
                            adapt.update_adaptive(&inst, t_max, tol);
                        let vf = fixed
                            .route(&inst)
                            .max_violation(&inst);
                        let va = adapt
                            .route(&inst)
                            .max_violation(&inst);
                        assert!(
                            va <= vf + tol as f64,
                            "n={n} tol={tol} skew={skew} seed={seed} \
                             b={b}: adaptive {va} fixed {vf}"
                        );
                    }
                    assert!(
                        total_iters < 4 * t_max,
                        "adaptive never early-exited (n={n} tol={tol} \
                         skew={skew} seed={seed})"
                    );
                }
            }
        }
    }

    #[test]
    fn adaptive_is_bit_identical_serial_vs_parallel_at_positive_tol() {
        let pool = Pool::new(3);
        for seed in [2u64, 13] {
            let mut serial = DualState::new(16);
            let mut parallel = DualState::new(16);
            let mut sa = ScoreArena::new();
            let mut pa = ScoreArena::new();
            for b in 0..3 {
                let inst = synth(5500 + 10 * seed + b, 511, 16, 4, 3.0);
                let si = serial
                    .update_adaptive_in(&inst, 16, 0.05, &mut sa);
                let pi = parallel.update_adaptive_parallel_in(
                    &inst, 16, 0.05, &pool, &mut pa,
                );
                assert_eq!(si, pi, "iters seed={seed} b={b}");
                assert_eq!(serial.q, parallel.q, "seed={seed} b={b}");
            }
        }
        pool.join();
    }
}
