//! Algorithm 4: constant-space online BIP balancing.
//!
//! Replaces Algorithm 3's per-expert top-heaps (O(n·k) floats across the
//! stream) with a per-expert histogram of b buckets over [0, 1): the
//! (nk/m + 1)-th largest reduced score is located by scanning cumulative
//! bucket counts from the top and linearly interpolating inside the
//! bucket. Space is O(m·b), independent of stream length — the property
//! §5.2 needs for recommendation-scale flows.

use crate::util::stats::{kth_largest_in_place, topk_indices, topk_into};

/// Per-expert histogram over [0,1) with `b` equal buckets.
///
/// Maintains suffix sums (`above[l]` = count of values in buckets > l) so
/// the rank query is a binary search instead of a top-down scan — pushes
/// are 1/token while queries are m*T/token, so the query side carries the
/// cost (EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u32>,
    above: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(b: usize) -> Self {
        assert!(b >= 1);
        Histogram { counts: vec![0; b], above: vec![0; b], total: 0 }
    }

    pub fn b(&self) -> usize {
        self.counts.len()
    }

    /// Record a value; negative values are skipped (Alg. 4 line 11 counts
    /// only s_j - p >= 0), values >= 1 clamp into the last bucket.
    pub fn push(&mut self, x: f32) {
        if x < 0.0 {
            return;
        }
        let b = self.counts.len();
        let idx = ((x as f64 * b as f64) as usize).min(b - 1);
        self.counts[idx] += 1;
        self.total += 1;
        for l in 0..idx {
            self.above[l] += 1;
        }
    }

    /// The raw bucket counts — the mergeable payload the replica-sync
    /// protocol ships between gates.
    pub fn counts(&self) -> &[u32] {
        &self.counts
    }

    /// Replace the contents with the given bucket counts, rebuilding
    /// the suffix sums and total. Used by the replica merge path.
    pub fn set_counts(&mut self, counts: &[u32]) {
        assert_eq!(counts.len(), self.counts.len());
        self.counts.copy_from_slice(counts);
        self.total = counts.iter().map(|&c| c as u64).sum();
        // above[l] = count of values recorded in buckets > l
        let mut running = 0u64;
        for l in (0..self.counts.len()).rev() {
            self.above[l] = running;
            running += self.counts[l] as u64;
        }
    }

    /// Interpolated value of the `rank`-th largest recorded value
    /// (1-based); None if fewer than `rank` values recorded.
    /// Alg. 4 line 12: find bucket l containing the rank, interpolate
    /// between l/b and (l+1)/b by the rank's position inside the bucket.
    pub fn kth_largest(&self, rank: u64) -> Option<f32> {
        self.kth_largest_with_extra(rank, usize::MAX)
    }

    /// `rank`-th largest of recorded ∪ {x} without mutating/cloning —
    /// the transient query Algorithm 4's T-loop issues per expert per
    /// iteration (perf: the naive clone-per-query was the Alg 4 hot spot,
    /// see EXPERIMENTS.md §Perf).
    pub fn kth_largest_with(&self, x: f32, rank: u64) -> Option<f32> {
        let extra = if x >= 0.0 {
            let b = self.counts.len();
            ((x as f64 * b as f64) as usize).min(b - 1)
        } else {
            usize::MAX
        };
        self.kth_largest_with_extra(rank, extra)
    }

    fn kth_largest_with_extra(&self, rank: u64, extra: usize)
        -> Option<f32>
    {
        let total =
            self.total + if extra != usize::MAX { 1 } else { 0 };
        if rank == 0 || total < rank {
            return None;
        }
        // cumulative count at-or-above bucket l, including the candidate
        let at_or_above = |l: usize| -> u64 {
            self.above[l]
                + self.counts[l] as u64
                + if extra != usize::MAX && extra >= l { 1 } else { 0 }
        };
        // smallest l is rank-heaviest; find the LARGEST l whose
        // at_or_above >= rank via binary search (at_or_above is
        // non-increasing in l)
        let (mut lo, mut hi) = (0usize, self.counts.len() - 1);
        if at_or_above(lo) < rank {
            return None;
        }
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if at_or_above(mid) >= rank {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let l = lo;
        let b = self.counts.len() as f64;
        let above =
            self.above[l] + u64::from(extra != usize::MAX && extra > l);
        let cnt = self.counts[l] as u64 + u64::from(extra == l);
        debug_assert!(above < rank && above + cnt >= rank);
        // it is the (rank - above)-th largest within bucket l
        let r = (rank - above) as f64;
        let frac = r / cnt as f64; // 0 < frac <= 1
        let hi_edge = (l as f64 + 1.0) / b;
        Some((hi_edge - frac / b) as f32)
    }
}

/// Algorithm 4 gate: like `OnlineGate` but with histogram state.
pub struct ApproxGate {
    pub m: usize,
    pub k: usize,
    pub cap: usize,
    pub t_iters: usize,
    pub q: Vec<f32>,
    hists: Vec<Histogram>,
    scratch: Vec<f32>,
}

impl ApproxGate {
    pub fn new(m: usize, k: usize, cap: usize, t_iters: usize, b: usize) -> Self {
        ApproxGate {
            m,
            k,
            cap,
            t_iters,
            q: vec![0.0; m],
            hists: (0..m).map(|_| Histogram::new(b)).collect(),
            scratch: vec![0.0; m],
        }
    }

    // COLD: allocating compat seam — serving routes through
    // `route_token_into`; the static hot-path lint stops here
    pub fn route_token(&mut self, scores: &[f32]) -> Vec<u32> {
        assert_eq!(scores.len(), self.m);
        for j in 0..self.m {
            self.scratch[j] = scores[j] - self.q[j];
        }
        let chosen: Vec<u32> = topk_indices(&self.scratch, self.k)
            .into_iter()
            .map(|e| e as u32)
            .collect();
        self.refine_and_absorb(scores);
        chosen
    }

    /// Allocation-free [`ApproxGate::route_token`]: identical decisions
    /// and histogram updates, chosen experts written into `out[..len]`
    /// with the caller's `idx` scratch (`idx.len() == m`).
    pub fn route_token_into(
        &mut self,
        scores: &[f32],
        idx: &mut [u32],
        out: &mut [u32],
    ) -> usize {
        assert_eq!(scores.len(), self.m);
        for j in 0..self.m {
            self.scratch[j] = scores[j] - self.q[j];
        }
        let len = topk_into(&self.scratch, self.k, idx, out);
        self.refine_and_absorb(scores);
        len
    }

    /// The T-iteration dual refinement + histogram absorption for one
    /// token (shared by both routing entry points).
    fn refine_and_absorb(&mut self, scores: &[f32]) {
        let kk = (self.k + 1).min(self.m);
        let rank = (self.cap + 1) as u64;
        let mut p = 0.0f32;
        for _ in 0..self.t_iters {
            for j in 0..self.m {
                self.scratch[j] = scores[j] - self.q[j];
            }
            p = kth_largest_in_place(&mut self.scratch, kk).max(0.0);
            for j in 0..self.m {
                // (cap+1)-th largest of hist ∪ {s_j - p}: clone-free query
                self.q[j] = self.hists[j]
                    .kth_largest_with(scores[j] - p, rank)
                    .unwrap_or(0.0)
                    .max(0.0);
            }
        }
        for j in 0..self.m {
            self.hists[j].push(scores[j] - p);
        }
    }

    /// Per-expert histogram bucket counts, for replica state export.
    pub fn hist_counts(&self) -> Vec<Vec<u32>> {
        self.hists.iter().map(|h| h.counts().to_vec()).collect()
    }

    /// Replace every expert histogram's contents (replica merge path).
    pub fn set_hist_counts(&mut self, counts: &[Vec<u32>]) {
        assert_eq!(counts.len(), self.hists.len());
        for (h, c) in self.hists.iter_mut().zip(counts) {
            h.set_counts(c);
        }
    }

    /// O(m·b) — independent of how many tokens have streamed through.
    pub fn state_bytes(&self) -> usize {
        self.hists
            .iter()
            .map(|h| h.counts.len() * 4 + h.above.len() * 8 + 8)
            .sum::<usize>()
            + self.q.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bip::online::OnlineGate;
    use crate::bip::Instance;
    use crate::util::rng::Pcg64;

    #[test]
    fn histogram_rank_query_brackets_truth() {
        let mut rng = Pcg64::new(1);
        let b = 64;
        let mut hist = Histogram::new(b);
        let mut vals: Vec<f32> = Vec::new();
        for _ in 0..500 {
            let x = rng.next_f32();
            hist.push(x);
            vals.push(x);
        }
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for rank in [1u64, 10, 100, 400] {
            let approx = hist.kth_largest(rank).unwrap();
            let truth = sorted[rank as usize - 1];
            assert!(
                (approx - truth).abs() <= 1.5 / b as f32,
                "rank {rank}: approx {approx} truth {truth}"
            );
        }
        assert_eq!(hist.kth_largest(501), None);
        assert_eq!(hist.kth_largest(0), None);
    }

    #[test]
    fn kth_largest_with_equals_clone_and_insert() {
        let mut rng = Pcg64::new(9);
        let mut hist = Histogram::new(32);
        for _ in 0..300 {
            let x = rng.next_f32() * 1.2 - 0.1; // includes negatives
            for rank in [1u64, 5, 50, 200] {
                let fast = hist.kth_largest_with(x, rank);
                let mut slow = hist.clone();
                slow.push(x);
                assert_eq!(fast, slow.kth_largest(rank));
            }
            hist.push(x);
        }
    }

    #[test]
    fn histogram_skips_negatives_clamps_high() {
        let mut h = Histogram::new(4);
        h.push(-0.5);
        assert_eq!(h.total, 0);
        h.push(1.5); // clamps into last bucket
        assert_eq!(h.total, 1);
        assert!(h.kth_largest(1).unwrap() > 0.74);
    }

    #[test]
    fn set_counts_round_trips_rank_queries() {
        let mut rng = Pcg64::new(5);
        let mut hist = Histogram::new(32);
        for _ in 0..200 {
            hist.push(rng.next_f32());
        }
        let mut rebuilt = Histogram::new(32);
        rebuilt.set_counts(hist.counts());
        assert_eq!(rebuilt.total, hist.total);
        for rank in [1u64, 7, 100, 200, 201] {
            assert_eq!(rebuilt.kth_largest(rank), hist.kth_largest(rank));
        }
        for x in [0.3f32, -0.1, 0.99] {
            assert_eq!(
                rebuilt.kth_largest_with(x, 50),
                hist.kth_largest_with(x, 50)
            );
        }
    }

    #[test]
    fn approx_tracks_online_balance() {
        let mut rng = Pcg64::new(2);
        let (n, m, k) = (1024usize, 16usize, 4usize);
        let inst = Instance::synthetic(n, m, k, 2.0, 3.0, &mut rng);
        let cap = n * k / m;
        let mut online = OnlineGate::new(m, k, cap, 4);
        let mut approx = ApproxGate::new(m, k, cap, 4, 128);
        let mut loads_o = vec![0u32; m];
        let mut loads_a = vec![0u32; m];
        for i in 0..n {
            for &e in &online.route_token(inst.row(i)) {
                loads_o[e as usize] += 1;
            }
            for &e in &approx.route_token(inst.row(i)) {
                loads_a[e as usize] += 1;
            }
        }
        let mean = (n * k / m) as f64;
        let vio_o = *loads_o.iter().max().unwrap() as f64 / mean - 1.0;
        let vio_a = *loads_a.iter().max().unwrap() as f64 / mean - 1.0;
        // the approximation stays within ~2x of the exact online variant
        assert!(vio_a <= (vio_o * 2.0).max(0.3),
                "approx {vio_a} online {vio_o}");
    }

    #[test]
    fn state_is_constant_in_stream_length() {
        let mut rng = Pcg64::new(3);
        let (m, k) = (8usize, 2usize);
        let mut gate = ApproxGate::new(m, k, 64, 2, 32);
        let mut first = None;
        for i in 0..500 {
            let inst = Instance::synthetic(1, m, k, 2.0, 1.0, &mut rng);
            gate.route_token(inst.row(0));
            if i == 10 {
                first = Some(gate.state_bytes());
            }
        }
        assert_eq!(gate.state_bytes(), first.unwrap());
        // O(m*b): 8 experts * 32 buckets * (4B count + 8B suffix) + overhead
        assert!(gate.state_bytes() <= 8 * 32 * 12 + 8 * 8 + m * 4);
    }

    #[test]
    fn more_buckets_means_better_dual_estimates() {
        let mut rng = Pcg64::new(4);
        let (n, m, k) = (512usize, 8usize, 2usize);
        let inst = Instance::synthetic(n, m, k, 2.0, 2.0, &mut rng);
        let cap = n * k / m;
        let mut err_by_b = Vec::new();
        for b in [8usize, 256] {
            let mut exact = OnlineGate::new(m, k, cap, 2);
            let mut approx = ApproxGate::new(m, k, cap, 2, b);
            for i in 0..n {
                exact.route_token(inst.row(i));
                approx.route_token(inst.row(i));
            }
            let err: f32 = exact
                .q
                .iter()
                .zip(&approx.q)
                .map(|(a, b)| (a - b).abs())
                .sum();
            err_by_b.push(err);
        }
        assert!(err_by_b[1] <= err_by_b[0] + 1e-4,
                "b=256 err {} b=8 err {}", err_by_b[1], err_by_b[0]);
    }
}
