//! Summary statistics and online accumulators used by metrics + benches.

use crate::perf::kernels;

/// Online mean/max/min/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        self.mean = (n1 * self.mean + n2 * other.mean) / (n1 + n2);
        self.m2 += other.m2 + delta * delta * n1 * n2 / (n1 + n2);
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact quantile over a collected sample (sorts a copy).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// k-th largest (1-based) — the order statistic at the heart of Alg. 1.
/// O(n) average via quickselect, no allocation beyond one scratch copy.
///
/// Out-of-range ranks are clamped into `1..=len`: `k = 0` answers the
/// maximum, `k > len` the minimum — callers sizing ranks from stream
/// parameters (`cap + 1`, `k + 1`) can never index out of bounds.
/// Panics on an empty slice (it has no order statistic at any rank).
pub fn kth_largest(xs: &[f32], k: usize) -> f32 {
    assert!(!xs.is_empty(), "kth_largest of an empty slice");
    let k = k.clamp(1, xs.len());
    let mut v = xs.to_vec();
    let idx = v.len() - k;
    // f32 total order is fine here: scores are finite softmax outputs.
    *v.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap()).1
}

/// In-place quickselect variant for hot loops that own a scratch
/// buffer. Same contract as [`kth_largest`]: rank clamped into
/// `1..=len`, panics on an empty slice.
pub fn kth_largest_in_place(v: &mut [f32], k: usize) -> f32 {
    assert!(!v.is_empty(), "kth_largest_in_place of an empty slice");
    let k = k.clamp(1, v.len());
    let idx = v.len() - k;
    *v.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap()).1
}

/// Monotone bijection f32 -> u32 (finite floats): integer comparisons are
/// ~3x cheaper than partial_cmp in quickselect's partition loop, which is
/// the dual solver's hot path (EXPERIMENTS.md §Perf).
#[inline]
pub fn f32_order_key(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

#[inline]
pub fn f32_from_order_key(k: u32) -> f32 {
    let b = if k & 0x8000_0000 != 0 { k & 0x7fff_ffff } else { !k };
    f32::from_bits(b)
}

/// k-th largest over a scratch buffer of order keys (integer
/// selection, rank-dispatched through `perf::kernels`). Same contract
/// as [`kth_largest`]: out-of-range ranks clamp into `1..=len`, and an
/// empty slice panics with a message (it has no order statistic at any
/// rank) — previously `k > len` underflowed `len - k` here.
// HOT: Algorithm 1 p/q-phase order statistic; no locks, no allocation
pub fn kth_largest_keys(v: &mut [u32], k: usize) -> f32 {
    assert!(!v.is_empty(), "kth_largest_keys of an empty slice");
    let k = k.clamp(1, v.len());
    f32_from_order_key(kernels::select_kth_key(v, k))
}

/// Allocation-free [`topk_indices`]: writes the indices of the k
/// largest values (descending, ties to the lower index) into
/// `out[..k]` using `idx` as index scratch (`idx.len() == xs.len()`).
/// Returns the number written (`k.min(xs.len())`). Dispatches into the
/// rank-specialized `perf::kernels` selection (insertion network /
/// fixed heap / comparator quickselect); every path selects the same
/// value-descending-ties-to-lower-index total order, so the output is
/// bit-identical to [`topk_indices`] regardless of which path k took —
/// the kernel property tests sweep the dispatch boundaries.
// HOT: per-token selection; no locks, no allocation
pub fn topk_into(
    xs: &[f32],
    k: usize,
    idx: &mut [u32],
    out: &mut [u32],
) -> usize {
    debug_assert_eq!(idx.len(), xs.len());
    kernels::topk_keys_into(xs, k, idx, out)
}

// COLD: allocating convenience wrapper — the serving hot path uses
// `topk_indices_into`; the static hot-path lint stops here
/// Indices of the k largest values, descending, ties broken by lower index
/// (matches jax.lax.top_k / the L1 gate kernel).
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let k = k.min(xs.len());
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b))
    });
    let mut top: Vec<usize> = idx[..k].to_vec();
    top.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
    top
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn summary_matches_naive() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..1000).map(|_| rng.normal() * 3.0 + 1.0).collect();
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((s.mean - mean).abs() < 1e-9);
        assert!((s.var() - var).abs() < 1e-9);
        assert_eq!(s.n, 1000);
    }

    #[test]
    fn summary_merge_equals_single_pass() {
        let mut rng = Pcg64::new(2);
        let xs: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..200] {
            a.push(x);
        }
        for &x in &xs[200..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean - whole.mean).abs() < 1e-12);
        assert!((a.var() - whole.var()).abs() < 1e-12);
        assert_eq!(a.min, whole.min);
        assert_eq!(a.max, whole.max);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert!((quantile(&xs, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn kth_largest_matches_sort() {
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            let n = 1 + rng.below(40) as usize;
            let xs: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for k in 1..=n {
                assert_eq!(kth_largest(&xs, k), sorted[k - 1]);
            }
        }
    }

    #[test]
    fn kth_largest_clamps_out_of_range_ranks() {
        let xs = [0.25f32, -1.0, 3.5, 0.0];
        // k = 0 clamps to the maximum, k > len to the minimum
        assert_eq!(kth_largest(&xs, 0), 3.5);
        assert_eq!(kth_largest(&xs, 1), 3.5);
        assert_eq!(kth_largest(&xs, 4), -1.0);
        assert_eq!(kth_largest(&xs, 99), -1.0);
        let mut v = xs.to_vec();
        assert_eq!(kth_largest_in_place(&mut v, 0), 3.5);
        let mut v = xs.to_vec();
        assert_eq!(kth_largest_in_place(&mut v, 99), -1.0);
        // singleton: every rank answers the only element
        assert_eq!(kth_largest(&[7.0], 0), 7.0);
        assert_eq!(kth_largest(&[7.0], 5), 7.0);
    }

    #[test]
    fn kth_largest_keys_clamps_out_of_range_ranks() {
        // the keys path clamps identically to kth_largest — previously
        // k = 0 / k > len underflowed `len - k` and panicked bare
        let xs = [0.25f32, -1.0, 3.5, 0.0];
        let keys = || -> Vec<u32> {
            xs.iter().map(|&x| f32_order_key(x)).collect()
        };
        assert_eq!(kth_largest_keys(&mut keys(), 0), 3.5);
        assert_eq!(kth_largest_keys(&mut keys(), 1), 3.5);
        assert_eq!(kth_largest_keys(&mut keys(), 4), -1.0);
        assert_eq!(kth_largest_keys(&mut keys(), 99), -1.0);
        // singleton: every rank answers the only element
        let mut one = [f32_order_key(7.0)];
        assert_eq!(kth_largest_keys(&mut one, 0), 7.0);
        let mut one = [f32_order_key(7.0)];
        assert_eq!(kth_largest_keys(&mut one, 5), 7.0);
    }

    #[test]
    #[should_panic(expected = "empty slice")]
    fn kth_largest_keys_of_empty_slice_panics_with_a_message() {
        kth_largest_keys(&mut [], 1);
    }

    #[test]
    #[should_panic(expected = "empty slice")]
    fn kth_largest_of_empty_slice_panics_with_a_message() {
        kth_largest(&[], 1);
    }

    #[test]
    #[should_panic(expected = "empty slice")]
    fn kth_largest_in_place_of_empty_slice_panics_with_a_message() {
        kth_largest_in_place(&mut [], 1);
    }

    #[test]
    fn topk_indices_match_reference() {
        let mut rng = Pcg64::new(4);
        for _ in 0..50 {
            let n = 2 + rng.below(30) as usize;
            let xs: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let got = topk_indices(&xs, k);
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| {
                xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b))
            });
            assert_eq!(got, want[..k].to_vec());
        }
    }

    #[test]
    fn topk_tie_break_lower_index() {
        let xs = [0.5f32, 0.9, 0.9, 0.1];
        assert_eq!(topk_indices(&xs, 2), vec![1, 2]);
    }

    #[test]
    fn topk_into_is_bit_identical_to_topk_indices() {
        let mut rng = Pcg64::new(17);
        for _ in 0..200 {
            let n = 1 + rng.below(40) as usize;
            // duplicate-heavy values exercise the tie-break
            let xs: Vec<f32> = (0..n)
                .map(|_| (rng.below(8) as f32) / 8.0)
                .collect();
            let k = rng.below(n as u64 + 2) as usize; // includes 0, > n
            let mut idx = vec![0u32; n];
            let mut out = vec![u32::MAX; n.max(k)];
            let wrote = topk_into(&xs, k, &mut idx, &mut out);
            let want = topk_indices(&xs, k);
            assert_eq!(wrote, want.len());
            let got: Vec<usize> =
                out[..wrote].iter().map(|&e| e as usize).collect();
            assert_eq!(got, want, "xs {xs:?} k {k}");
        }
    }

    #[test]
    fn order_key_is_monotone_bijection() {
        let mut rng = Pcg64::new(8);
        let mut vals: Vec<f32> = (0..500)
            .map(|_| (rng.next_f32() - 0.5) * 100.0)
            .collect();
        vals.extend([0.0, -0.0, 1.0, -1.0, f32::MIN_POSITIVE]);
        for &v in &vals {
            let rt = f32_from_order_key(f32_order_key(v));
            assert!(rt == v || (rt == 0.0 && v == 0.0), "{v} -> {rt}");
        }
        let mut sorted_f = vals.clone();
        sorted_f.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut sorted_k = vals.clone();
        sorted_k.sort_by_key(|&v| f32_order_key(v));
        for (a, b) in sorted_f.iter().zip(&sorted_k) {
            assert_eq!(a.to_bits() & 0x7fff_ffff != 0,
                       b.to_bits() & 0x7fff_ffff != 0);
            assert!((a - b).abs() == 0.0);
        }
    }

    #[test]
    fn kth_largest_keys_matches_float_path() {
        let mut rng = Pcg64::new(12);
        for _ in 0..40 {
            let n = 2 + rng.below(60) as usize;
            let xs: Vec<f32> =
                (0..n).map(|_| rng.next_f32() - 0.3).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let mut keys: Vec<u32> =
                xs.iter().map(|&x| f32_order_key(x)).collect();
            assert_eq!(kth_largest_keys(&mut keys, k),
                       kth_largest(&xs, k));
        }
    }
}
