//! PCG64 (XSL-RR 128/64) pseudo-random generator + distribution helpers.
//!
//! Deterministic, seedable, and fast enough for the data pipeline and the
//! cluster simulator; no external `rand` crate is available offline.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream (e.g. one per data-loader shard).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf distribution over {0..n-1} with exponent `s`, via precomputed CDF.
/// Used by the synthetic corpus to match natural-language token skew.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let r = rng.next_f64();
        self.cdf.partition_point(|&c| c < r).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(1, 0);
        let mut b = Pcg64::with_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Pcg64::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
        assert_eq!(counts.iter().sum::<usize>(), 50_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_indices() {
        let mut rng = Pcg64::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2);
    }
}
