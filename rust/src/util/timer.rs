//! Wall-clock timing helpers for the bench harness and the training loop.

use std::time::{Duration, Instant};

/// Scoped stopwatch with named laps (for per-phase breakdowns).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d) in &self.laps {
            s.push_str(&format!("{name}: {}  ", human_duration(*d)));
        }
        s.push_str(&format!("total: {}", human_duration(self.total())));
        s
    }
}

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Repeat a closure until `min_time` elapses (>= 1 iteration), returning
/// (iters, mean seconds/iter). The bench harness's inner loop.
pub fn bench_loop(min_time: Duration, mut f: impl FnMut()) -> (u64, f64) {
    // warmup
    f();
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed() < min_time {
        f();
        iters += 1;
    }
    (iters, t0.elapsed().as_secs_f64() / iters as f64)
}

/// Render a duration with a unit that keeps 3-5 significant digits.
///
/// The unit is chosen by what the *rounded* value needs, so boundary
/// durations never render as e.g. `999.996ns -> "1000.00ns"`; they
/// promote to `"1.00µs"` (pinned by the round-trip test below).
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 120.0 {
        return format!("{:.1}min", s / 60.0);
    }
    let mut v = s * 1e9;
    for unit in ["ns", "µs", "ms"] {
        // two decimals are printed, so promote once round(v * 100)
        // would need four integer digits
        if (v * 100.0).round() < 100_000.0 {
            return format!("{v:.2}{unit}");
        }
        v /= 1000.0;
    }
    format!("{v:.2}s")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("b");
        assert_eq!(sw.laps.len(), 2);
        assert!(sw.laps[0].1 >= Duration::from_millis(2));
        assert!(sw.total() >= Duration::from_millis(3));
        assert!(sw.report().contains("a:"));
    }

    #[test]
    fn bench_loop_runs_at_least_once() {
        let (iters, per) = bench_loop(Duration::from_millis(1), || {
            std::hint::black_box(1 + 1);
        });
        assert!(iters >= 1);
        assert!(per >= 0.0);
    }

    #[test]
    fn human_readable() {
        assert!(human_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(human_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(human_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(human_duration(Duration::from_secs(5)).ends_with('s'));
        assert!(human_duration(Duration::from_secs(300)).ends_with("min"));
    }

    #[test]
    fn human_duration_round_trips_across_unit_boundaries() {
        // (duration, exact rendering) spanning ns/µs/ms/s/min,
        // including the promote-at-the-boundary cases that used to
        // render as "1000.00ns" / "0.0ns"
        let cases: &[(Duration, &str)] = &[
            (Duration::from_nanos(0), "0.00ns"),
            (Duration::from_nanos(1), "1.00ns"),
            (Duration::from_nanos(999), "999.00ns"),
            (Duration::from_nanos(1_000), "1.00µs"),
            (Duration::from_nanos(999_996), "1.00ms"),
            (Duration::from_micros(1), "1.00µs"),
            (Duration::from_micros(1_500), "1.50ms"),
            (Duration::from_millis(999), "999.00ms"),
            (Duration::from_millis(1_000), "1.00s"),
            (Duration::from_secs_f64(1.234), "1.23s"),
            (Duration::from_secs(119), "119.00s"),
            (Duration::from_secs(120), "2.0min"),
            (Duration::from_secs(300), "5.0min"),
        ];
        for (d, want) in cases {
            assert_eq!(&human_duration(*d), want, "{d:?}");
        }
        // parse back numeric prefix: value must match the duration to
        // within rendering precision (0.5% at 3 significant digits)
        for (d, _) in cases {
            let text = human_duration(*d);
            let unit_at = text
                .find(|c: char| c != '.' && !c.is_ascii_digit())
                .unwrap();
            let num: f64 = text[..unit_at].parse().unwrap();
            let scale = match &text[unit_at..] {
                "ns" => 1e-9,
                "µs" => 1e-6,
                "ms" => 1e-3,
                "s" => 1.0,
                "min" => 60.0,
                u => panic!("unexpected unit {u:?}"),
            };
            let secs = d.as_secs_f64();
            assert!(
                (num * scale - secs).abs() <= secs * 0.005 + 1e-11,
                "{text} does not round-trip to {secs}s"
            );
        }
    }

    #[test]
    fn report_uses_human_units() {
        let mut sw = Stopwatch::new();
        sw.laps.push(("fast".into(), Duration::from_nanos(250)));
        sw.laps.push(("slow".into(), Duration::from_millis(12)));
        let r = sw.report();
        assert!(r.contains("fast: 250.00ns"), "{r}");
        assert!(r.contains("slow: 12.00ms"), "{r}");
        assert!(r.contains("total:"), "{r}");
    }
}
