//! Wall-clock timing helpers for the bench harness and the training loop.

use std::time::{Duration, Instant};

/// Scoped stopwatch with named laps (for per-phase breakdowns).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d) in &self.laps {
            s.push_str(&format!("{name}: {:.3}s  ", d.as_secs_f64()));
        }
        s.push_str(&format!("total: {:.3}s", self.total().as_secs_f64()));
        s
    }
}

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Repeat a closure until `min_time` elapses (>= 1 iteration), returning
/// (iters, mean seconds/iter). The bench harness's inner loop.
pub fn bench_loop(min_time: Duration, mut f: impl FnMut()) -> (u64, f64) {
    // warmup
    f();
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed() < min_time {
        f();
        iters += 1;
    }
    (iters, t0.elapsed().as_secs_f64() / iters as f64)
}

pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("b");
        assert_eq!(sw.laps.len(), 2);
        assert!(sw.laps[0].1 >= Duration::from_millis(2));
        assert!(sw.total() >= Duration::from_millis(3));
        assert!(sw.report().contains("a:"));
    }

    #[test]
    fn bench_loop_runs_at_least_once() {
        let (iters, per) = bench_loop(Duration::from_millis(1), || {
            std::hint::black_box(1 + 1);
        });
        assert!(iters >= 1);
        assert!(per >= 0.0);
    }

    #[test]
    fn human_readable() {
        assert!(human_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(human_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(human_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(human_duration(Duration::from_secs(5)).ends_with('s'));
        assert!(human_duration(Duration::from_secs(300)).ends_with("min"));
    }
}
