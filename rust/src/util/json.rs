//! Minimal-but-complete JSON: recursive-descent parser + emitter.
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py` and
//! serializes reports/checkpoint metadata. Handles the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null); numbers
//! are kept as f64 (ample for shapes/offsets < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field.array[2].leaf` style access for tests/tools.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            if let Some(open) = part.find('[') {
                let (name, idx) = part.split_at(open);
                if !name.is_empty() {
                    cur = cur.get(name)?;
                }
                for seg in idx.split('[').skip(1) {
                    let i: usize = seg.trim_end_matches(']').parse().ok()?;
                    cur = cur.as_arr()?.get(i)?;
                }
            } else {
                cur = cur.get(part)?;
            }
        }
        Some(cur)
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let code = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            if (0xd800..=0xdbff).contains(&code) {
                                // high surrogate: JSON encodes astral
                                // characters as a \uD8xx\uDCxx pair
                                let paired = self.bytes.get(self.pos + 1)
                                    == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2)
                                        == Some(&b'u');
                                let lo = if paired {
                                    Some(self.hex4(self.pos + 3)?)
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo)
                                        if (0xdc00..=0xdfff)
                                            .contains(&lo) =>
                                    {
                                        self.pos += 6;
                                        let c = 0x10000
                                            + ((code - 0xd800) << 10)
                                            + (lo - 0xdc00);
                                        out.push(
                                            char::from_u32(c)
                                                .unwrap_or('\u{fffd}'),
                                        );
                                    }
                                    // unpaired high surrogate: replace
                                    // it, leave what follows intact
                                    _ => out.push('\u{fffd}'),
                                }
                            } else if (0xdc00..=0xdfff).contains(&code) {
                                // lone low surrogate
                                out.push('\u{fffd}');
                            } else {
                                out.push(
                                    char::from_u32(code)
                                        .unwrap_or('\u{fffd}'),
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    /// Four hex digits starting at `start` (a `\uXXXX` payload).
    fn hex4(&self, start: usize) -> Result<u32, ParseError> {
        let hex = self
            .bytes
            .get(start..start + 4)
            .ok_or_else(|| self.err("bad \\u"))?;
        let s = std::str::from_utf8(hex)
            .map_err(|_| self.err("bad \\u"))?;
        if !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u"));
        }
        u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u"))
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_like() {
        let text = r#"{
            "fingerprint": "abc123",
            "configs": {"tiny": {"theta_size": 74400, "lr": 3e-4,
                "params": [{"name": "embed", "shape": [512, 32],
                            "offset": 0, "decay": true}]}},
            "artifacts": [{"file": "x.hlo.txt", "bip_T": 4, "neg": -2.5,
                           "none": null, "flag": false}]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.path("configs.tiny.theta_size").unwrap().as_usize(),
                   Some(74400));
        assert_eq!(
            v.path("configs.tiny.params[0].shape[1]").unwrap().as_usize(),
            Some(32));
        assert_eq!(v.path("artifacts[0].neg").unwrap().as_f64(), Some(-2.5));
        assert_eq!(v.path("artifacts[0].none").unwrap(), &Json::Null);
        assert_eq!(v.path("configs.tiny.lr").unwrap().as_f64(), Some(3e-4));
        // emit -> reparse -> equal
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let emitted = Json::Str("x\"y\n\t\\".into()).to_string();
        assert_eq!(Json::parse(&emitted).unwrap().as_str(),
                   Some("x\"y\n\t\\"));
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn surrogate_pairs_decode_to_astral_chars() {
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap().as_str(),
            Some("😀")
        );
        assert_eq!(
            Json::parse(r#""x\uD83D\uDE00!""#).unwrap().as_str(),
            Some("x😀!")
        );
        // two pairs back to back
        assert_eq!(
            Json::parse(r#""\ud83d\ude00\ud83d\ude01""#)
                .unwrap()
                .as_str(),
            Some("😀😁")
        );
        // lone surrogates are replaced, not fatal (emitters should never
        // produce them, but foreign JSON can)
        assert_eq!(
            Json::parse(r#""\ud83d""#).unwrap().as_str(),
            Some("\u{fffd}")
        );
        assert_eq!(
            Json::parse(r#""\ude00""#).unwrap().as_str(),
            Some("\u{fffd}")
        );
        // a high surrogate followed by an ordinary char or escape keeps
        // the follower intact
        assert_eq!(
            Json::parse(r#""\ud83dA""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        assert_eq!(
            Json::parse(r#""\ud83d\u0041""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        // truncated or non-hex \u payloads still error
        assert!(Json::parse("\"\\ud8\"").is_err());
        assert!(Json::parse("\"\\uzzzz\"").is_err());
        assert!(Json::parse("\"\\u+123\"").is_err());
    }

    #[test]
    fn string_escape_round_trip_property() {
        // every emitted string must reparse to itself: control chars,
        // DEL, BMP text, astral-plane chars (the trace JSON export
        // leans on this)
        use crate::util::rng::Pcg64;
        let mut pool: Vec<char> = (0u32..0x20)
            .map(|c| char::from_u32(c).unwrap())
            .collect();
        pool.extend([
            '\u{7f}', '"', '\\', '/', ' ', 'é', '世', '\u{fffd}',
            '\u{d7ff}', '\u{e000}', '😀', '🧮', '\u{10ffff}',
        ]);
        pool.extend('a'..='e');
        let mut rng = Pcg64::new(99);
        for _ in 0..300 {
            let len = rng.below(24) as usize;
            let s: String = (0..len)
                .map(|_| pool[rng.below(pool.len() as u64) as usize])
                .collect();
            let emitted = Json::Str(s.clone()).to_string();
            let parsed = Json::parse(&emitted)
                .unwrap_or_else(|e| panic!("{e} on {emitted:?}"));
            assert_eq!(parsed.as_str(), Some(s.as_str()), "{emitted:?}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers() {
        for (t, want) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0),
                          ("2.5E-2", 0.025), ("123456789", 123456789.0)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(want), "{t}");
        }
    }

    #[test]
    fn integral_emission() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
