//! Leveled stderr logging with elapsed-time prefix. `BIP_MOE_LOG`
//! env var selects the level (error|warn|info|debug|trace), default info.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init_from_env() {
    let lvl = match std::env::var("BIP_MOE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = START.set(Instant::now());
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, msg: std::fmt::Arguments) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
