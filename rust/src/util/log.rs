//! Leveled stderr logging with a monotonic elapsed-time prefix.
//! `BIP_MOE_LOG` selects the level (error|warn|info|debug|trace,
//! default info); `BIP_LOG_FORMAT=json` switches to JSON-lines output
//! (`{"t":…,"level":"…","msg":"…"}`) so log lines can be joined with
//! telemetry snapshots on the shared `elapsed_secs` clock. Plain text
//! stays the default.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Output shape for log lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// `[    0.123s INFO ] message`
    Plain = 0,
    /// one JSON object per line, keys `t` / `level` / `msg`
    Json = 1,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static FORMAT: AtomicU8 = AtomicU8::new(0);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init_from_env() {
    let lvl = match std::env::var("BIP_MOE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    if std::env::var("BIP_LOG_FORMAT").as_deref() == Ok("json") {
        set_format(Format::Json);
    }
    let _ = START.set(Instant::now());
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn set_format(fmt: Format) {
    FORMAT.store(fmt as u8, Ordering::Relaxed);
}

pub fn format() -> Format {
    if FORMAT.load(Ordering::Relaxed) == Format::Json as u8 {
        Format::Json
    } else {
        Format::Plain
    }
}

pub fn enabled(lvl: Level) -> bool {
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Monotonic seconds since logging started (process-relative; the
/// same clock telemetry snapshot timestamps are correlated against).
pub fn elapsed_secs() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(lvl: Level, msg: std::fmt::Arguments) {
    if !enabled(lvl) {
        return;
    }
    let t = elapsed_secs();
    match format() {
        Format::Plain => {
            let tag = match lvl {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {tag}] {msg}");
        }
        Format::Json => {
            // logging is off the hot path, so rendering through the
            // JSON escaper (allocates) is fine here
            let body =
                crate::util::json::Json::Str(msg.to_string());
            eprintln!(
                "{{\"t\":{t:.6},\"level\":\"{}\",\"msg\":{body}}}",
                lvl.name()
            );
        }
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn format_toggles_and_defaults_to_plain() {
        assert_eq!(format(), Format::Plain);
        set_format(Format::Json);
        assert_eq!(format(), Format::Json);
        set_format(Format::Plain);
        assert_eq!(format(), Format::Plain);
    }

    #[test]
    fn elapsed_is_monotonic() {
        let a = elapsed_secs();
        let b = elapsed_secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn json_lines_are_valid_json() {
        // render the same payload `log` emits in JSON mode and make
        // sure tricky messages survive the escaper
        for msg in ["plain", "with \"quotes\"", "tab\tand\nnewline"] {
            let body = crate::util::json::Json::Str(msg.to_string());
            let line = format!(
                "{{\"t\":{:.6},\"level\":\"info\",\"msg\":{body}}}",
                0.25f64
            );
            let doc =
                crate::util::json::Json::parse(&line).expect(msg);
            assert_eq!(
                doc.path("msg").and_then(|j| j.as_str()),
                Some(msg)
            );
            assert_eq!(
                doc.path("level").and_then(|j| j.as_str()),
                Some("info")
            );
        }
    }
}
