//! Support substrates built from scratch for this crate.
//!
//! The offline build environment provides no tokio/serde/clap/criterion/rand,
//! so the pieces a framework normally pulls from crates.io are implemented
//! (and unit-tested) here: a PCG64 RNG, a JSON parser/emitter, CSV writing,
//! a CLI argument parser, summary statistics, wall-clock timers and a
//! bounded-channel thread pool.

pub mod args;
pub mod csv;
pub mod json;
pub mod log;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use args::Args;
pub use json::Json;
pub use rng::Pcg64;
