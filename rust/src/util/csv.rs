//! CSV writing for figure/table series (reports/*.csv consumed by any
//! plotting tool). Quoting per RFC 4180 where needed.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

pub struct CsvWriter<W: Write> {
    out: W,
    cols: usize,
}

impl CsvWriter<BufWriter<File>> {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = File::create(path)?;
        Self::new(BufWriter::new(file), header)
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut out: W, header: &[&str]) -> io::Result<Self> {
        write_row(&mut out, header.iter().map(|s| s.to_string()))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    pub fn row<I, S>(&mut self, fields: I) -> io::Result<()>
    where
        I: IntoIterator<Item = S>,
        S: ToString,
    {
        let items: Vec<String> =
            fields.into_iter().map(|f| f.to_string()).collect();
        assert_eq!(
            items.len(),
            self.cols,
            "csv row width {} != header width {}",
            items.len(),
            self.cols
        );
        write_row(&mut self.out, items)
    }

    pub fn row_mixed(&mut self, fields: &[CsvField]) -> io::Result<()> {
        self.row(fields.iter().map(|f| f.render()))
    }

    pub fn finish(mut self) -> io::Result<()> {
        self.out.flush()
    }
}

pub enum CsvField {
    Int(i64),
    Float(f64),
    Str(String),
}

impl CsvField {
    fn render(&self) -> String {
        match self {
            CsvField::Int(x) => x.to_string(),
            CsvField::Float(x) => format!("{x:.6}"),
            CsvField::Str(s) => s.clone(),
        }
    }
}

fn write_row<W: Write, I: IntoIterator<Item = String>>(
    out: &mut W,
    fields: I,
) -> io::Result<()> {
    let mut first = true;
    for field in fields {
        if !first {
            write!(out, ",")?;
        }
        first = false;
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            write!(out, "\"{}\"", field.replace('"', "\"\""))?;
        } else {
            write!(out, "{field}")?;
        }
    }
    writeln!(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &["step", "maxvio"]).unwrap();
            w.row(["0", "1.5"]).unwrap();
            w.row([1.to_string(), format!("{:.4}", 0.25)]).unwrap();
            w.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(text, "step,maxvio\n0,1.5\n1,0.2500\n");
    }

    #[test]
    fn quotes_when_needed() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
            w.row(["x,y", "he said \"hi\""]).unwrap();
            w.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn width_mismatch_panics() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
        let _ = w.row(["only-one"]);
    }
}
