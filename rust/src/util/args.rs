//! Tiny CLI argument parser (no clap offline): subcommand + `--key value`
//! / `--key=value` / boolean `--flag` options, with typed accessors and an
//! unknown-option check so typos fail loudly.
//!
//! Typed accessors return [`ArgError`] instead of panicking: a user
//! typo on the command line must come back as an `error:` line naming
//! the offending flag and what it wants, never a panic backtrace.

use std::collections::BTreeMap;

/// A malformed option value: names the flag, the rejected value, and
/// what the flag wants, in the same listing style as the unknown
/// scenario/policy errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgError {
    pub flag: String,
    pub value: String,
    pub wants: &'static str,
}

impl ArgError {
    fn new(flag: &str, value: &str, wants: &'static str) -> ArgError {
        ArgError {
            flag: flag.to_string(),
            value: value.to_string(),
            wants,
        }
    }
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid value '{}' for --{}; wants {}",
            self.value, self.flag, self.wants
        )
    }
}

impl std::error::Error for ArgError {}

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter
                    .next_if(|n| !n.starts_with("--"))
                {
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".into());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::new(key, v, "an unsigned integer")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::new(key, v, "an unsigned integer")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| ArgError::new(key, v, "a number"))
            }
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on options outside the allowed set (catches typos).
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.options.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k}; allowed: {}",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --config moe16 --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("moe16"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("bench --mode=bip --t=4 --lr=2.5e-4");
        assert_eq!(a.get("mode"), Some("bip"));
        assert_eq!(a.usize_or("t", 0).unwrap(), 4);
        assert!((a.f64_or("lr", 0.0).unwrap() - 2.5e-4).abs() < 1e-12);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn positional_args() {
        let a = parse("solve file1.json file2.json --out x");
        assert_eq!(a.subcommand.as_deref(), Some("solve"));
        assert_eq!(a.positional, vec!["file1.json", "file2.json"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn unknown_check() {
        let a = parse("x --good 1 --bad 2");
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn negative_number_value() {
        let a = parse("x --bias -0.5");
        // "-0.5" does not start with --, so it is consumed as the value
        assert!((a.f64_or("bias", 0.0).unwrap() + 0.5).abs() < 1e-12);
    }

    #[test]
    fn malformed_value_names_the_flag() {
        let a = parse("train --steps banana --lr fast");
        let err = a.usize_or("steps", 0).expect_err("banana is not a usize");
        let msg = err.to_string();
        assert!(msg.contains("--steps"), "flag missing from: {msg}");
        assert!(msg.contains("banana"), "value missing from: {msg}");
        assert!(msg.contains("unsigned integer"), "wants missing from: {msg}");
        let err = a.f64_or("lr", 0.0).expect_err("fast is not a float");
        assert!(err.to_string().contains("--lr"));
        assert_eq!(a.u64_or("steps", 0).expect_err("still bad").flag, "steps");
    }
}
