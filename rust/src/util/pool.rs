//! Minimal thread pool + bounded SPSC channel (no tokio offline).
//!
//! Used by the data loader (prefetch with backpressure), the cluster
//! simulator (per-device workers), the parallel Algorithm 1 dual update
//! (`bip::dual::DualState::update_parallel`), and the replica-sharded
//! serving engine (`serve::replica::ReplicaSet`).
//!
//! Two properties matter for the nested uses:
//!
//! * **panic safety** — a job that panics still counts toward its
//!   batch's completion (drop-guard), the first payload is re-raised on
//!   the waiting side, and the worker thread survives to take the next
//!   job;
//! * **no nested-wait deadlock** — a thread blocked in [`Pool::map`] or
//!   [`Pool::scoped_run`] *helps*: it pops pending jobs off the queue
//!   and runs them inline instead of sleeping, so pool jobs may
//!   themselves fan out onto the same pool (the serving engine routes R
//!   micro-batches in parallel while each router's Algorithm 1 update
//!   chunks rows/columns onto the very same workers).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Bounded multi-producer multi-consumer blocking channel.
pub struct Bounded<T> {
    inner: Arc<BoundedInner<T>>,
}

struct BoundedInner<T> {
    queue: Mutex<BoundedState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { inner: self.inner.clone() }
    }
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Bounded {
            inner: Arc::new(BoundedInner {
                queue: Mutex::new(BoundedState {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocks while full (this is the loader's backpressure).
    /// Returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.inner.cap {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: Err(item) when full or closed. The pool's
    /// nested fan-out path uses this so a worker thread never blocks on
    /// its own queue (which could deadlock once every worker does it).
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed || st.items.len() >= self.inner.cap {
            return Err(item);
        }
        st.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocks while empty; None once closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Completion latch for one `map`/`scoped_run` batch: counts finished
/// jobs (panicked ones included) and stores the first panic payload so
/// the waiting side can re-raise it.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    done: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Latch {
    fn new() -> Arc<Latch> {
        Arc::new(Latch {
            state: Mutex::new(LatchState { done: 0, panic: None }),
            cv: Condvar::new(),
        })
    }
}

/// Counts one job on drop. Completion is signalled from a destructor so
/// that a panicking job still counts: without this, `map` waits for a
/// completion that never comes (the pre-fix deadlock).
struct CountGuard(Arc<Latch>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.done += 1;
        self.0.cv.notify_all();
    }
}

/// Run one latch-tracked job body: the guard counts it no matter what,
/// and the first panic payload is parked in the latch for re-raising.
fn run_counted(latch: &Arc<Latch>, body: impl FnOnce()) {
    let guard = CountGuard(latch.clone());
    if let Err(payload) = catch_unwind(AssertUnwindSafe(body)) {
        let mut st = guard.0.state.lock().unwrap();
        if st.panic.is_none() {
            st.panic = Some(payload);
        }
    }
}

/// Fixed-size worker pool executing boxed jobs; join waits for quiescence.
pub struct Pool {
    tx: Bounded<Job>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let tx = Bounded::<Job>::new(threads * 4);
        let workers = (0..threads)
            .map(|_| {
                let rx = tx.clone();
                std::thread::spawn(move || {
                    while let Some(job) = rx.recv() {
                        // keep the worker alive across panicking jobs;
                        // latch-tracked jobs re-raise on the waiting side
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                })
            })
            .collect();
        Pool { tx, workers, threads }
    }

    /// Number of worker threads (parallel chunking sizes against this).
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .send(Box::new(job))
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Enqueue, or run inline when the queue is full: a worker fanning
    /// out onto its own pool must never block on the bounded queue.
    fn spawn_or_run(&self, job: Job) {
        if let Err(job) = self.tx.try_send(job) {
            job();
        }
    }

    /// Wait for `n` latch-tracked jobs, helping with queued work instead
    /// of sleeping so that nested waits cannot starve the pool.
    fn wait(&self, latch: &Arc<Latch>, n: usize) {
        loop {
            // completion first: a finished batch must not be held
            // hostage by an unrelated queued job
            if latch.state.lock().unwrap().done >= n {
                return;
            }
            if let Some(job) = self.tx.try_recv() {
                // a helped job may be a foreign raw spawn(); contain its
                // panic like the worker loop does — an unwind escaping
                // here would abandon in-flight latch jobs mid-wait
                let _ = catch_unwind(AssertUnwindSafe(job));
                continue;
            }
            let st = latch.state.lock().unwrap();
            if st.done >= n {
                return;
            }
            // the timeout is load-bearing, not belt-and-braces: the
            // latch condvar is only notified by completions, so a job
            // enqueued after the try_recv above (by a nested fan-out on
            // another thread) is otherwise invisible until the next
            // completion — the poll bounds that window
            let (st, _timed_out) = latch
                .cv
                .wait_timeout(st, Duration::from_millis(5))
                .unwrap();
            if st.done >= n {
                return;
            }
        }
    }

    /// Re-raise the first panic a batch of jobs captured, if any.
    fn rethrow(latch: &Latch) {
        let payload = latch.state.lock().unwrap().panic.take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    /// Run a closure over each item in parallel, preserving order of
    /// results. A panicking closure does not deadlock the pool: every
    /// job counts toward completion via a drop-guard, and the first
    /// panic is re-raised here after all jobs have settled.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let latch = Latch::new();
        for (i, item) in items.into_iter().enumerate() {
            let f = f.clone();
            let results = results.clone();
            let latch = latch.clone();
            self.spawn_or_run(Box::new(move || {
                run_counted(&latch, move || {
                    let r = f(item);
                    results.lock().unwrap()[i] = Some(r);
                });
            }));
        }
        self.wait(&latch, n);
        Self::rethrow(&latch);
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }

    /// Execute `f(0) .. f(n-1)` across the pool, blocking until every
    /// call has finished. Unlike [`Pool::map`], `f` may borrow caller
    /// state (a scoped API): the borrow is erased to ship jobs to the
    /// workers, which is sound because this function does not return —
    /// or unwind — before every job has completed. Jobs are counted by
    /// drop-guards (panics included) and run under `catch_unwind`, so
    /// no unwind can escape a job while the erased borrow is live; the
    /// first panic is re-raised here once all jobs have settled.
    ///
    /// Dispatch is a broadcast: at most `min(n, threads)` jobs are
    /// enqueued (one heap box each — the Algorithm 1 hot path calls
    /// this 2T times per batch, so the old one-box-per-index scheme
    /// was measurable churn), and the jobs pull indices from a shared
    /// atomic cursor. A panicking index stops only its own puller; the
    /// remaining jobs drain the rest of the index space, and the first
    /// panic payload is re-raised here after the batch settles.
    pub fn scoped_run<F>(&self, n: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        match n {
            0 => return,
            1 => return f(0),
            _ => {}
        }
        let latch = Latch::new();
        let next = AtomicUsize::new(0);
        let jobs = n.min(self.threads);
        let fp = f as *const F as usize;
        let np = &next as *const AtomicUsize as usize;
        for _ in 0..jobs {
            let latch = latch.clone();
            self.spawn_or_run(Box::new(move || {
                run_counted(&latch, || {
                    // SAFETY: `fp` points at the caller's `f`, which
                    // outlives every job — scoped_run only returns
                    // after the latch counts all `jobs` completions
                    let f = unsafe { &*(fp as *const F) };
                    // SAFETY: `np` points at `next` on scoped_run's
                    // stack frame, alive for the same latch-bounded
                    // extent as `fp` above
                    let next = unsafe { &*(np as *const AtomicUsize) };
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        f(i);
                    }
                });
            }));
        }
        self.wait(&latch, jobs);
        Self::rethrow(&latch);
    }

    /// Explicit quiescent shutdown (also runs on drop).
    pub fn join(self) {}
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_fifo_and_close() {
        let ch = Bounded::new(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        ch.close();
        assert_eq!(ch.recv(), None);
        assert!(ch.send(3).is_err());
    }

    #[test]
    fn try_send_bounces_on_full_and_closed() {
        let ch = Bounded::new(1);
        assert!(ch.try_send(1).is_ok());
        assert_eq!(ch.try_send(2), Err(2));
        assert_eq!(ch.recv(), Some(1));
        ch.close();
        assert_eq!(ch.try_send(3), Err(3));
    }

    #[test]
    fn backpressure_blocks_producer() {
        let ch = Bounded::new(2);
        let tx = ch.clone();
        let produced = Arc::new(AtomicUsize::new(0));
        let pc = produced.clone();
        let h = std::thread::spawn(move || {
            for i in 0..6 {
                tx.send(i).unwrap();
                pc.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        // producer must be stuck at capacity (2 in queue, maybe 1 in flight)
        assert!(produced.load(Ordering::SeqCst) <= 3);
        let mut got = Vec::new();
        for _ in 0..6 {
            got.push(ch.recv().unwrap());
        }
        h.join().unwrap();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..32).collect(), |x: i32| x * x);
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
        pool.join();
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_propagates_panics_without_deadlock() {
        // regression: a panicking job used to leave the completion
        // counter short of n forever — map would never return
        let pool = Pool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..8).collect::<Vec<i32>>(), |x| {
                if x == 3 {
                    panic!("boom");
                }
                x * 2
            })
        }));
        assert!(caught.is_err(), "panic must re-propagate to the caller");
        // the pool (workers included) survives and keeps serving
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        pool.join();
    }

    #[test]
    fn scoped_run_borrows_caller_state() {
        let pool = Pool::new(3);
        let data: Vec<usize> = (0..100).collect();
        let partial = Mutex::new(vec![0usize; 7]);
        let f = |c: usize| {
            let lo = c * 15;
            let hi = (lo + 15).min(data.len());
            let s: usize = data[lo..hi].iter().sum();
            partial.lock().unwrap()[c] = s;
        };
        pool.scoped_run(7, &f);
        let total: usize = partial.lock().unwrap().iter().sum();
        assert_eq!(total, 100 * 99 / 2);
        pool.join();
    }

    #[test]
    fn scoped_run_covers_every_index_exactly_once() {
        // broadcast dispatch: min(n, threads) pullers must still visit
        // the whole index space exactly once
        let pool = Pool::new(2);
        let hits: Vec<AtomicUsize> =
            (0..100).map(|_| AtomicUsize::new(0)).collect();
        let f = |i: usize| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        };
        pool.scoped_run(100, &f);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
        pool.join();
    }

    #[test]
    fn scoped_run_propagates_panics() {
        let pool = Pool::new(2);
        let f = |c: usize| {
            if c == 2 {
                panic!("chunk failure");
            }
        };
        let caught =
            catch_unwind(AssertUnwindSafe(|| pool.scoped_run(4, &f)));
        assert!(caught.is_err());
        pool.join();
    }

    #[test]
    fn nested_fan_out_does_not_deadlock() {
        // every worker blocks in a nested scoped_run; help-while-wait
        // must keep the queue draining
        let pool = Arc::new(Pool::new(2));
        let inner_pool = pool.clone();
        let out = pool.map((0..8).collect::<Vec<usize>>(), move |x| {
            let acc = Mutex::new(0usize);
            let f = |c: usize| {
                *acc.lock().unwrap() += c + x;
            };
            inner_pool.scoped_run(4, &f);
            let got = *acc.lock().unwrap();
            got
        });
        let want: Vec<usize> = (0..8).map(|x| 6 + 4 * x).collect();
        assert_eq!(out, want);
    }
}
