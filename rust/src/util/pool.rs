//! Minimal thread pool + bounded SPSC channel (no tokio offline).
//!
//! Used by the data loader (prefetch with backpressure) and the cluster
//! simulator (per-device workers).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Bounded multi-producer multi-consumer blocking channel.
pub struct Bounded<T> {
    inner: Arc<BoundedInner<T>>,
}

struct BoundedInner<T> {
    queue: Mutex<BoundedState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { inner: self.inner.clone() }
    }
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Bounded {
            inner: Arc::new(BoundedInner {
                queue: Mutex::new(BoundedState {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocks while full (this is the loader's backpressure).
    /// Returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.inner.cap {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Blocks while empty; None once closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Fixed-size worker pool executing boxed jobs; join waits for quiescence.
pub struct Pool {
    tx: Bounded<Job>,
    workers: Vec<JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl Pool {
    pub fn new(threads: usize) -> Self {
        let tx = Bounded::<Job>::new(threads.max(1) * 4);
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = tx.clone();
                std::thread::spawn(move || {
                    while let Some(job) = rx.recv() {
                        job();
                    }
                })
            })
            .collect();
        Pool { tx, workers }
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .send(Box::new(job))
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Run a closure over each item in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for (i, item) in items.into_iter().enumerate() {
            let f = f.clone();
            let results = results.clone();
            let done = done.clone();
            self.spawn(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }

    pub fn join(self) {
        self.tx.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_fifo_and_close() {
        let ch = Bounded::new(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        ch.close();
        assert_eq!(ch.recv(), None);
        assert!(ch.send(3).is_err());
    }

    #[test]
    fn backpressure_blocks_producer() {
        let ch = Bounded::new(2);
        let tx = ch.clone();
        let produced = Arc::new(AtomicUsize::new(0));
        let pc = produced.clone();
        let h = std::thread::spawn(move || {
            for i in 0..6 {
                tx.send(i).unwrap();
                pc.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        // producer must be stuck at capacity (2 in queue, maybe 1 in flight)
        assert!(produced.load(Ordering::SeqCst) <= 3);
        let mut got = Vec::new();
        for _ in 0..6 {
            got.push(ch.recv().unwrap());
        }
        h.join().unwrap();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..32).collect(), |x: i32| x * x);
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
        pool.join();
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
