//! Data pipeline: synthetic pre-training corpus + batch loader.
//!
//! The paper trains on the Minimind corpus (Chinese web text, vocab 6400).
//! That corpus is not available here, so [`corpus`] builds the closest
//! synthetic equivalent that exercises the same code paths: a Zipf-mixture
//! Markov token stream over the same 6400-token vocabulary (natural-language
//! token frequencies are Zipfian, and router score skew — the thing load
//! balancing reacts to — tracks that skew). See DESIGN.md §Substitutions.
//!
//! [`loader`] shards the stream into fixed-shape (batch, seq+1) i32 batches
//! with a deterministic train/test split and a prefetch thread bounded by a
//! backpressure channel.

pub mod corpus;
pub mod loader;

pub use corpus::{Corpus, CorpusSpec};
pub use loader::{Batch, Loader, Split};
