//! Synthetic pre-training corpus: a Zipf-mixture Markov chain over a fixed
//! vocabulary.
//!
//! Construction: `n_topics` latent topics, each with its own Zipf-permuted
//! unigram distribution; a document samples a topic, then emits tokens from
//! a first-order Markov blend (with probability `coherence` the next token
//! is drawn from a deterministic successor table seeded per topic,
//! otherwise from the topic's unigram Zipf). This produces:
//!   * a global Zipfian marginal (like real text),
//!   * topic-dependent co-occurrence structure (so a language model can
//!     actually reduce loss by learning), and
//!   * token-distribution skew that induces unbalanced router scores —
//!     the phenomenon the paper's algorithm exists to fix.

use crate::util::rng::{Pcg64, Zipf};

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub vocab_size: usize,
    pub n_topics: usize,
    pub zipf_exponent: f64,
    pub coherence: f64,
    pub doc_len: usize,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            vocab_size: 6400,
            n_topics: 16,
            zipf_exponent: 1.05,
            coherence: 0.55,
            doc_len: 512,
            seed: 20240601,
        }
    }
}

pub struct Corpus {
    spec: CorpusSpec,
    zipf: Zipf,
    /// per-topic permutation of the vocab (rank -> token id)
    topic_perm: Vec<Vec<u32>>,
    /// per-topic successor table token -> next token (coherent bigrams)
    successor: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn build(spec: CorpusSpec) -> Corpus {
        let mut rng = Pcg64::with_stream(spec.seed, 7);
        let zipf = Zipf::new(spec.vocab_size, spec.zipf_exponent);
        let mut topic_perm = Vec::with_capacity(spec.n_topics);
        let mut successor = Vec::with_capacity(spec.n_topics);
        for _ in 0..spec.n_topics {
            // banded shuffle: permute ranks only within windows of 64 so
            // every topic keeps the same global Zipf head/tail structure
            // (the marginal stays skewed like real text) while topics
            // still differ in WHICH head token goes where.
            let mut perm: Vec<u32> = (0..spec.vocab_size as u32).collect();
            for band in perm.chunks_mut(64) {
                rng.shuffle(band);
            }
            // successors drawn through the SAME Zipf so the coherent
            // branch preserves the heavy-tailed marginal (uniform
            // successors would flatten it)
            let succ: Vec<u32> = (0..spec.vocab_size)
                .map(|_| perm[zipf.sample(&mut rng)])
                .collect();
            topic_perm.push(perm);
            successor.push(succ);
        }
        Corpus { spec, zipf, topic_perm, successor }
    }

    pub fn spec(&self) -> &CorpusSpec {
        &self.spec
    }

    /// Generate document `doc_id` deterministically (same id -> same doc).
    pub fn document(&self, doc_id: u64) -> Vec<u32> {
        let mut rng = Pcg64::with_stream(self.spec.seed ^ 0x9e37, doc_id);
        let topic = rng.below(self.spec.n_topics as u64) as usize;
        let perm = &self.topic_perm[topic];
        let succ = &self.successor[topic];
        let mut out = Vec::with_capacity(self.spec.doc_len);
        let mut prev = perm[self.zipf.sample(&mut rng)];
        out.push(prev);
        for _ in 1..self.spec.doc_len {
            let tok = if rng.next_f64() < self.spec.coherence {
                succ[prev as usize]
            } else {
                perm[self.zipf.sample(&mut rng)]
            };
            out.push(tok);
            prev = tok;
        }
        out
    }

    /// Infinite deterministic token stream = concatenated documents.
    pub fn stream(&self, start_doc: u64) -> TokenStream<'_> {
        TokenStream { corpus: self, doc: start_doc, buf: Vec::new(), pos: 0 }
    }
}

pub struct TokenStream<'a> {
    corpus: &'a Corpus,
    doc: u64,
    buf: Vec<u32>,
    pos: usize,
}

impl Iterator for TokenStream<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        if self.pos >= self.buf.len() {
            self.buf = self.corpus.document(self.doc);
            self.doc += 1;
            self.pos = 0;
        }
        let tok = self.buf[self.pos];
        self.pos += 1;
        Some(tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CorpusSpec {
        CorpusSpec { vocab_size: 256, n_topics: 4, doc_len: 128,
                     ..Default::default() }
    }

    #[test]
    fn documents_are_deterministic() {
        let c = Corpus::build(small_spec());
        assert_eq!(c.document(5), c.document(5));
        assert_ne!(c.document(5), c.document(6));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::build(small_spec());
        for d in 0..20 {
            assert!(c.document(d).iter().all(|&t| (t as usize) < 256));
        }
    }

    #[test]
    fn marginal_is_skewed() {
        let c = Corpus::build(small_spec());
        let mut counts = vec![0usize; 256];
        for t in c.stream(0).take(100_000) {
            counts[t as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // head is much heavier than the tail (Zipf-like marginal)
        let head: usize = sorted[..16].iter().sum();
        let tail: usize = sorted[128..].iter().sum();
        assert!(head > 3 * tail, "head {head} tail {tail}");
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // coherent successors: P(next | prev) concentrates vs unigram
        let c = Corpus::build(small_spec());
        let toks: Vec<u32> = c.stream(0).take(200_000).collect();
        let mut pair_counts = std::collections::HashMap::new();
        let mut prev_counts = vec![0usize; 256];
        for w in toks.windows(2) {
            *pair_counts.entry((w[0], w[1])).or_insert(0usize) += 1;
            prev_counts[w[0] as usize] += 1;
        }
        // for frequent prev tokens, the argmax successor should hold a
        // large share (near `coherence`)
        let mut checked = 0;
        for prev in 0..256u32 {
            if prev_counts[prev as usize] < 500 {
                continue;
            }
            let best = (0..256u32)
                .map(|nxt| *pair_counts.get(&(prev, nxt)).unwrap_or(&0))
                .max()
                .unwrap();
            // the stream mixes n_topics successor tables, so the dominant
            // successor's share is ~coherence/n_topics at worst; far above
            // the uniform 1/vocab ~ 0.004 baseline either way
            let share = best as f64 / prev_counts[prev as usize] as f64;
            assert!(share > 0.10, "prev {prev} share {share}");
            checked += 1;
        }
        assert!(checked > 5);
    }

    #[test]
    fn stream_crosses_document_boundaries() {
        let c = Corpus::build(small_spec());
        let n = 128 * 3 + 17;
        let toks: Vec<u32> = c.stream(0).take(n).collect();
        assert_eq!(toks.len(), n);
        let d0 = c.document(0);
        assert_eq!(&toks[..128], &d0[..]);
    }
}
