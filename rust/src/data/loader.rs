//! Batch loader: deterministic train/test split over the document space,
//! fixed-shape (batch, seq+1) i32 batches, and an optional prefetch thread
//! with bounded-channel backpressure so data generation overlaps PJRT
//! execution without unbounded memory growth.

use std::sync::Arc;

use super::corpus::Corpus;
use crate::util::pool::Bounded;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One LM batch: `tokens` is row-major (batch_size, seq_len + 1) — inputs
/// are [:, :-1], targets [:, 1:], exactly what the AOT train step expects.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch_size: usize,
    pub seq_len: usize,
    pub index: u64,
}

impl Batch {
    pub fn n_tokens(&self) -> usize {
        self.batch_size * self.seq_len
    }
}

pub struct Loader {
    corpus: Arc<Corpus>,
    batch_size: usize,
    seq_len: usize,
    split: Split,
    /// every `test_mod`-th document is held out for the test split
    test_mod: u64,
}

impl Loader {
    pub fn new(
        corpus: Arc<Corpus>,
        batch_size: usize,
        seq_len: usize,
        split: Split,
    ) -> Loader {
        Loader { corpus, batch_size, seq_len, split, test_mod: 10 }
    }

    fn doc_for(&self, logical: u64) -> u64 {
        // interleave: docs with id % test_mod == 0 belong to Test
        match self.split {
            Split::Test => logical * self.test_mod,
            Split::Train => {
                let per_block = self.test_mod - 1;
                let block = logical / per_block;
                let off = logical % per_block;
                block * self.test_mod + 1 + off
            }
        }
    }

    /// Deterministic batch by index (same index -> same tokens), each row
    /// drawn from its own document sequence so rows are independent.
    pub fn batch(&self, index: u64) -> Batch {
        let row_len = self.seq_len + 1;
        let mut tokens = Vec::with_capacity(self.batch_size * row_len);
        for row in 0..self.batch_size as u64 {
            let logical_doc =
                index * self.batch_size as u64 + row;
            let doc = self.doc_for(logical_doc);
            let mut stream = self.corpus.stream(doc);
            for _ in 0..row_len {
                tokens.push(stream.next().unwrap() as i32);
            }
        }
        Batch {
            tokens,
            batch_size: self.batch_size,
            seq_len: self.seq_len,
            index,
        }
    }

    /// Spawn a prefetch thread producing batches [start, start+count);
    /// the bounded channel (depth `depth`) provides backpressure.
    pub fn prefetch(
        self: Arc<Self>,
        start: u64,
        count: u64,
        depth: usize,
    ) -> Bounded<Batch> {
        let ch = Bounded::new(depth);
        let tx = ch.clone();
        let loader = self;
        std::thread::spawn(move || {
            for i in start..start + count {
                if tx.send(loader.batch(i)).is_err() {
                    break; // consumer closed early
                }
            }
            tx.close();
        });
        ch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;

    fn corpus() -> Arc<Corpus> {
        Arc::new(Corpus::build(CorpusSpec {
            vocab_size: 256,
            n_topics: 4,
            doc_len: 64,
            ..Default::default()
        }))
    }

    #[test]
    fn batch_shape_and_determinism() {
        let loader = Loader::new(corpus(), 4, 32, Split::Train);
        let b = loader.batch(3);
        assert_eq!(b.tokens.len(), 4 * 33);
        assert_eq!(b.n_tokens(), 128);
        assert_eq!(loader.batch(3).tokens, b.tokens);
        assert_ne!(loader.batch(4).tokens, b.tokens);
    }

    #[test]
    fn train_and_test_documents_are_disjoint() {
        let c = corpus();
        let train = Loader::new(c.clone(), 1, 8, Split::Train);
        let test = Loader::new(c, 1, 8, Split::Test);
        let train_docs: Vec<u64> = (0..100).map(|i| train.doc_for(i)).collect();
        let test_docs: Vec<u64> = (0..20).map(|i| test.doc_for(i)).collect();
        for td in &test_docs {
            assert!(!train_docs.contains(td), "doc {td} leaked");
            assert_eq!(td % 10, 0);
        }
        for td in &train_docs {
            assert_ne!(td % 10, 0);
        }
        // no duplicates within a split
        let mut uniq = train_docs.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), train_docs.len());
    }

    #[test]
    fn rows_use_distinct_documents() {
        let loader = Loader::new(corpus(), 4, 32, Split::Train);
        let b = loader.batch(0);
        let row0 = &b.tokens[..33];
        let row1 = &b.tokens[33..66];
        assert_ne!(row0, row1);
    }

    #[test]
    fn prefetch_delivers_in_order_with_backpressure() {
        let loader = Arc::new(Loader::new(corpus(), 2, 16, Split::Train));
        let ch = loader.clone().prefetch(5, 20, 2);
        let mut idx = 5;
        while let Some(b) = ch.recv() {
            assert_eq!(b.index, idx);
            assert_eq!(b.tokens, loader.batch(idx).tokens);
            idx += 1;
        }
        assert_eq!(idx, 25);
    }

    #[test]
    fn prefetch_consumer_can_abandon() {
        let loader = Arc::new(Loader::new(corpus(), 2, 16, Split::Train));
        let ch = loader.prefetch(0, 1000, 2);
        let _ = ch.recv();
        ch.close(); // producer unblocks and exits
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}
