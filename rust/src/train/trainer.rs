//! The training coordinator: drives the AOT train/eval steps from rust,
//! records balance/loss metrics, accounts perplexity on the held-out
//! split, and feeds measured load vectors to the cluster simulator —
//! everything Tables 2-5 and Figures 1-18 are computed from.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::{Corpus, CorpusSpec, Loader, Split};
use crate::metrics::{Perplexity, RunRecorder};
use crate::parallel::{ClusterSim, DeviceProfile, Mesh, ModelCost};
use crate::prof::{Frame, ProfGuard};
use crate::runtime::{Engine, Tensor};
use crate::telemetry;
use crate::train::state::TrainState;
use crate::util::json::Json;

/// One training run's setup: which artifact (config x mode x T), how many
/// steps, seeds and eval budget.
#[derive(Clone, Debug)]
pub struct TrainDriver {
    pub config: String,
    pub mode: String,       // "aux" | "lossfree" | "bip"
    pub bip_t: usize,       // used when mode == "bip"
    pub steps: u64,
    pub seed: i32,
    pub eval_batches: u64,
    pub data_seed: u64,
    /// devices for the simulated expert-parallel cluster
    pub sim_devices: usize,
    /// warm-start step 0's route state (the per-layer dual/bias tensor)
    /// from a prior run's recorded serving trace, via a quick forecast
    /// fit (`forecast::control::route_state_seed`)
    pub warm_start_trace: Option<std::path::PathBuf>,
}

impl TrainDriver {
    pub fn new(config: &str, mode: &str, bip_t: usize, steps: u64) -> Self {
        TrainDriver {
            config: config.to_string(),
            mode: mode.to_string(),
            bip_t,
            steps,
            seed: 0,
            eval_batches: 8,
            data_seed: 20240601,
            sim_devices: 4,
            warm_start_trace: None,
        }
    }

    pub fn run_label(&self) -> String {
        if self.mode == "bip" {
            format!("{}_bip_T{}", self.config, self.bip_t)
        } else {
            format!("{}_{}", self.config, self.mode)
        }
    }

    /// Execute the full run. Artifacts must already be built.
    pub fn run(&self, engine: &Engine) -> Result<TrainOutcome> {
        let cfg = engine.manifest().config(&self.config)?.clone();
        let train_art = engine
            .manifest()
            .train_artifact(&self.config, &self.mode, self.bip_t)?
            .clone();
        let eval_art = engine
            .manifest()
            .find(&self.config, "eval", &self.mode, None)?
            .clone();
        let init_art =
            engine.manifest().find(&self.config, "init", "-", None)?.clone();

        // data pipeline: synthetic corpus at the model's vocab, prefetch
        // thread with backpressure
        let corpus = Arc::new(Corpus::build(CorpusSpec {
            vocab_size: cfg.vocab_size,
            seed: self.data_seed,
            ..Default::default()
        }));
        let train_loader = Arc::new(Loader::new(
            corpus.clone(),
            cfg.batch_size,
            cfg.seq_len,
            Split::Train,
        ));
        let batches = train_loader.clone().prefetch(0, self.steps, 4);

        // init params on device
        let theta = engine
            .run(&init_art, &[Tensor::scalar_i32(self.seed)])?
            .pop()
            .unwrap();
        let mut state = TrainState::fresh(theta, &cfg);
        if let Some(path) = &self.warm_start_trace {
            // balance from step 0: fit a forecast on the prior run's
            // load trajectory and seed every layer's routing state.
            // The in-graph sign differs by mode (model.py): BIP
            // *subtracts* its duals q, Loss-Free *adds* its bias —
            // so the bias consumer takes the negated seed; aux never
            // reads route_state at all.
            let trace = crate::trace::Trace::load(path)?;
            let mut seed = crate::forecast::route_state_seed(
                &trace,
                cfg.n_layers,
                cfg.n_experts,
                cfg.top_k,
                crate::forecast::DEFAULT_SEED_GAIN,
            )
            .with_context(|| {
                format!("warm-starting from {}", path.display())
            })?;
            match self.mode.as_str() {
                "bip" => {}
                "lossfree" => {
                    for x in seed.iter_mut() {
                        *x = -*x;
                    }
                }
                other => anyhow::bail!(
                    "--warm-start-trace needs a routing state to seed \
                     (mode bip or lossfree), but mode is {other}"
                ),
            }
            state.route_state = Tensor::from_f32(
                &[cfg.n_layers, cfg.n_experts],
                seed,
            );
            crate::info!(
                "{}: route_state warm-started from {}",
                self.run_label(),
                path.display()
            );
        }

        // simulated expert-parallel cluster fed by measured loads
        let profile = if cfg.n_experts >= 64 {
            DeviceProfile::l20()
        } else {
            DeviceProfile::rtx4090()
        };
        let cost = if cfg.n_experts >= 64 {
            ModelCost::paper_64e()
        } else {
            ModelCost::paper_16e()
        };
        let mut sim = ClusterSim::new(
            Mesh::new(self.sim_devices, cfg.n_experts),
            profile,
            cost,
            self.mode == "aux",
        )
        .with_paper_batch(cfg.n_tokens);

        let mut rec = RunRecorder::new(
            &self.run_label(),
            cfg.n_layers,
            cfg.n_tokens,
            cfg.top_k,
        );
        rec.set_meta("config", Json::Str(self.config.clone()));
        rec.set_meta("mode", Json::Str(self.mode.clone()));
        rec.set_meta("bip_T", Json::Num(self.bip_t as f64));
        rec.set_meta("theta_size", Json::Num(cfg.theta_size as f64));

        let m = cfg.n_experts;
        let n_tok = cfg.n_tokens as f32;
        while let Some(batch) = batches.recv() {
            let tokens = Tensor::from_i32(
                &[cfg.batch_size, cfg.seq_len + 1],
                batch.tokens.clone(),
            );
            let step_span =
                telemetry::Span::enter(telemetry::SpanKind::TrainStep);
            let step_prof = ProfGuard::enter(Frame::TrainStep);
            let t0 = Instant::now();
            let outputs = engine
                .run(&train_art, &state.as_inputs(tokens))
                .with_context(|| format!("train step {}", batch.index))?;
            let wall = t0.elapsed().as_secs_f64() as f32;
            let rest = state.absorb(outputs);
            let nll = rest[0].scalar_f32()?;
            let loads = rest[1].f32s()?;
            let drops = rest[2].f32s()?;
            let mean_drop =
                drops.iter().sum::<f32>() / drops.len().max(1) as f32;
            sim.push_step(loads, m);
            rec.push_step(loads, m, nll / n_tok, mean_drop, wall);
            drop(step_prof);
            drop(step_span);
            telemetry::counter_add(telemetry::Counter::TrainSteps, 1);
            if let Some(&v) = rec.balance.global_series.last() {
                telemetry::gauge_set(
                    telemetry::Gauge::TrainLastMaxVio,
                    v as f64,
                );
            }
            if batch.index % 20 == 0 {
                crate::info!(
                    "{} step {:>4} loss {:.4} maxvio {:.4} drop {:.4}",
                    self.run_label(),
                    batch.index,
                    nll / n_tok,
                    rec.balance.global_series.last().unwrap(),
                    mean_drop
                );
            }
        }

        // held-out perplexity with frozen routing state
        let test_loader =
            Loader::new(corpus, cfg.batch_size, cfg.seq_len, Split::Test);
        let mut ppl = Perplexity::default();
        for i in 0..self.eval_batches {
            let batch = test_loader.batch(i);
            let tokens = Tensor::from_i32(
                &[cfg.batch_size, cfg.seq_len + 1],
                batch.tokens,
            );
            let outs = engine.run(
                &eval_art,
                &[
                    state.theta.clone(),
                    state.route_state.clone(),
                    tokens,
                ],
            )?;
            ppl.push(outs[0].scalar_f32()? as f64, cfg.n_tokens as u64);
        }

        rec.set_meta("perplexity", Json::Num(ppl.value()));
        rec.set_meta("sim_hours", Json::Num(sim.total_hours()));
        rec.set_meta(
            "sim_hours_full",
            Json::Num(sim.extrapolate_hours(cfg.total_steps as u64)),
        );
        rec.set_meta("sim_profile", Json::Str(sim.profile.name.into()));

        Ok(TrainOutcome { recorder: rec, perplexity: ppl.value(), sim,
                          state })
    }
}

pub struct TrainOutcome {
    pub recorder: RunRecorder,
    pub perplexity: f64,
    pub sim: ClusterSim,
    pub state: TrainState,
}

impl TrainOutcome {
    /// The paper's Table 2/3 row for this run.
    pub fn table_row(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            format!("{:.4}", self.recorder.balance.avg_max_vio()),
            format!("{:.4}", self.recorder.balance.sup_max_vio()),
            format!("{:.4}", self.perplexity),
            format!("{:.4}", self.sim.extrapolate_hours(
                self.sim.steps.max(1))),
        ]
    }

    pub fn dump(&self, reports_dir: &Path) -> Result<std::path::PathBuf> {
        Ok(self.recorder.dump(reports_dir)?)
    }
}
