//! Training state: the five threaded arrays of the AOT train step
//! (theta, adam m/v, step counter, routing state) plus a simple binary
//! checkpoint format.
//!
//! Checkpoint layout: magic `BIPMOE1\n`, u32 little-endian JSON-header
//! length, JSON header (config, mode, shapes), then each tensor's raw
//! little-endian payload in header order.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ModelConfig;
use crate::runtime::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"BIPMOE1\n";

#[derive(Clone, Debug)]
pub struct TrainState {
    pub theta: Tensor,
    pub adam_m: Tensor,
    pub adam_v: Tensor,
    pub step: Tensor,
    pub route_state: Tensor,
}

impl TrainState {
    /// Fresh optimizer/routing state around an initialized theta.
    pub fn fresh(theta: Tensor, cfg: &ModelConfig) -> TrainState {
        let n = theta.len();
        TrainState {
            theta,
            adam_m: Tensor::zeros_f32(&[n]),
            adam_v: Tensor::zeros_f32(&[n]),
            step: Tensor::scalar_i32(0),
            route_state: Tensor::zeros_f32(&[cfg.n_layers, cfg.n_experts]),
        }
    }

    pub fn step_count(&self) -> i32 {
        self.step.i32s().map(|s| s[0]).unwrap_or(0)
    }

    /// Inputs for the train artifact, in manifest order, tokens appended
    /// by the caller.
    pub fn as_inputs(&self, tokens: Tensor) -> Vec<Tensor> {
        vec![
            self.theta.clone(),
            self.adam_m.clone(),
            self.adam_v.clone(),
            self.step.clone(),
            self.route_state.clone(),
            tokens,
        ]
    }

    /// Absorb the train step's first five outputs back into the state.
    pub fn absorb(&mut self, mut outputs: Vec<Tensor>) -> Vec<Tensor> {
        let rest = outputs.split_off(5);
        let mut it = outputs.into_iter();
        self.theta = it.next().unwrap();
        self.adam_m = it.next().unwrap();
        self.adam_v = it.next().unwrap();
        self.step = it.next().unwrap();
        self.route_state = it.next().unwrap();
        rest
    }

    pub fn save(&self, path: &Path, config: &str, mode: &str) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tensors: Vec<(&str, &Tensor)> = vec![
            ("theta", &self.theta),
            ("adam_m", &self.adam_m),
            ("adam_v", &self.adam_v),
            ("step", &self.step),
            ("route_state", &self.route_state),
        ];
        let header = Json::obj(vec![
            ("config", Json::Str(config.into())),
            ("mode", Json::Str(mode.into())),
            ("version", Json::Str(crate::VERSION.into())),
            (
                "tensors",
                Json::Arr(
                    tensors
                        .iter()
                        .map(|(name, t)| {
                            Json::obj(vec![
                                ("name", Json::Str((*name).into())),
                                ("shape", Json::Arr(
                                    t.shape()
                                        .iter()
                                        .map(|&d| Json::Num(d as f64))
                                        .collect())),
                                ("dtype", Json::Str(match t {
                                    Tensor::F32 { .. } => "f32".into(),
                                    Tensor::I32 { .. } => "i32".into(),
                                })),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in tensors {
            match t {
                Tensor::F32 { data, .. } => {
                    for x in data {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for x in data {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<(TrainState, String, String)> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening checkpoint {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a bip-moe checkpoint");
        }
        let mut len_bytes = [0u8; 4];
        f.read_exact(&mut len_bytes)?;
        let header_len = u32::from_le_bytes(len_bytes) as usize;
        let mut header_bytes = vec![0u8; header_len];
        f.read_exact(&mut header_bytes)?;
        let header = Json::parse(std::str::from_utf8(&header_bytes)?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let config = header
            .get("config")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mode = header
            .get("mode")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut tensors = Vec::new();
        for tj in header
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("bad checkpoint header"))?
        {
            let shape: Vec<usize> = tj
                .get("shape")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let count = shape.iter().product::<usize>().max(1);
            let dtype = tj.get("dtype").and_then(Json::as_str).unwrap_or("f32");
            let t = match dtype {
                "f32" => {
                    let mut data = vec![0f32; count];
                    let mut buf = vec![0u8; count * 4];
                    f.read_exact(&mut buf)?;
                    for (i, ch) in buf.chunks_exact(4).enumerate() {
                        data[i] =
                            f32::from_le_bytes(ch.try_into().unwrap());
                    }
                    Tensor::F32 { shape, data }
                }
                "i32" => {
                    let mut data = vec![0i32; count];
                    let mut buf = vec![0u8; count * 4];
                    f.read_exact(&mut buf)?;
                    for (i, ch) in buf.chunks_exact(4).enumerate() {
                        data[i] =
                            i32::from_le_bytes(ch.try_into().unwrap());
                    }
                    Tensor::I32 { shape, data }
                }
                other => bail!("bad dtype {other}"),
            };
            tensors.push(t);
        }
        if tensors.len() != 5 {
            bail!("checkpoint has {} tensors, wanted 5", tensors.len());
        }
        let route_state = tensors.pop().unwrap();
        let step = tensors.pop().unwrap();
        let adam_v = tensors.pop().unwrap();
        let adam_m = tensors.pop().unwrap();
        let theta = tensors.pop().unwrap();
        Ok((
            TrainState { theta, adam_m, adam_v, step, route_state },
            config,
            mode,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab_size: 16,
            d_model: 4,
            n_heads: 2,
            n_layers: 2,
            d_ff: 4,
            n_experts: 4,
            top_k: 2,
            seq_len: 8,
            batch_size: 2,
            n_tokens: 16,
            capacity: 16,
            expert_cap: 8,
            theta_size: 10,
            total_steps: 100,
            params: vec![],
        }
    }

    #[test]
    fn fresh_state_shapes() {
        let cfg = tiny_cfg();
        let st = TrainState::fresh(Tensor::zeros_f32(&[10]), &cfg);
        assert_eq!(st.adam_m.len(), 10);
        assert_eq!(st.route_state.shape(), &[2, 4]);
        assert_eq!(st.step_count(), 0);
    }

    #[test]
    fn absorb_splits_outputs() {
        let cfg = tiny_cfg();
        let mut st = TrainState::fresh(Tensor::zeros_f32(&[10]), &cfg);
        let outs = vec![
            Tensor::from_f32(&[10], vec![1.0; 10]),
            Tensor::zeros_f32(&[10]),
            Tensor::zeros_f32(&[10]),
            Tensor::scalar_i32(1),
            Tensor::zeros_f32(&[2, 4]),
            Tensor::from_f32(&[], vec![3.25]),  // nll
            Tensor::zeros_f32(&[2, 4]),          // loads
            Tensor::zeros_f32(&[2]),             // drops
        ];
        let rest = st.absorb(outs);
        assert_eq!(st.step_count(), 1);
        assert_eq!(st.theta.f32s().unwrap()[0], 1.0);
        assert_eq!(rest.len(), 3);
        assert_eq!(rest[0].scalar_f32().unwrap(), 3.25);
    }

    #[test]
    fn checkpoint_round_trip() {
        let cfg = tiny_cfg();
        let mut st = TrainState::fresh(Tensor::zeros_f32(&[10]), &cfg);
        st.theta = Tensor::from_f32(&[10],
                                    (0..10).map(|i| i as f32).collect());
        st.step = Tensor::scalar_i32(42);
        let path = std::env::temp_dir().join(format!(
            "bipmoe-ckpt-{}.bin", std::process::id()));
        st.save(&path, "tiny", "bip").unwrap();
        let (loaded, config, mode) = TrainState::load(&path).unwrap();
        assert_eq!(config, "tiny");
        assert_eq!(mode, "bip");
        assert_eq!(loaded.theta, st.theta);
        assert_eq!(loaded.step_count(), 42);
        assert_eq!(loaded.route_state.shape(), &[2, 4]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join(format!(
            "bipmoe-garbage-{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(TrainState::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
