pub mod state; pub mod trainer; pub use trainer::{TrainDriver, TrainOutcome};
