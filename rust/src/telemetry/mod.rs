//! Live observability for the whole stack (ISSUE 6).
//!
//! Three pieces:
//!
//! * [`registry`] — the static, fully preallocated metrics registry:
//!   closed enums of counters/gauges/fixed-bucket histograms, written
//!   through per-thread shards with relaxed (saturating) atomics. The
//!   write path performs **zero heap allocations**, so the PR-5
//!   steady-state gate (`tests/integration_perf.rs`) holds with
//!   telemetry on.
//! * [`span`] — RAII span timers ([`Span::enter`] … drop) feeding the
//!   matching histogram plus a bounded global ring of recent spans.
//! * [`expose`] — scrape-side snapshots: shard merging, bucket
//!   quantiles, and Prometheus-text / JSON writers. Only scrapes
//!   allocate.
//!
//! The instrumented sites (see DESIGN.md for the full map):
//! `serve::router` (batches, tokens, overflow, batch MaxVio, routed
//! tokens per (layer, expert), sampled top-K-vs-argmax agreement),
//! `serve::sim` (queue depth, shed), `serve::replica` (dispatch spans,
//! merge-sync counts and divergence), `routing`/`bip::dual` (solve
//! spans, iteration counts, MaxVio and calm-column trajectories),
//! `forecast` (eval samples, MAE), and `train` (step spans, MaxVio).
//!
//! Read it back out with `bip-moe metrics` (attach + periodic deltas),
//! `bip-moe serve --metrics-out snap.json`, or programmatically via
//! [`scrape`]`(`[`global`]`())`. Traces (v3+) embed a scrape so replay
//! can diff recorded-vs-replayed metrics.

pub mod expose;
pub mod registry;
pub mod span;

pub use expose::{
    scrape, scrape_named, HistSnapshot, Snapshot, PROM_PREFIX,
    SNAPSHOT_FORMAT, SNAPSHOT_VERSION,
};
pub use registry::{
    counter_add, enabled, expert_tokens_add, expert_tokens_add_f32,
    gauge_set, global, hist_observe, set_enabled, Counter, Gauge,
    Hist, Registry,
};
pub use span::{elapsed_secs, recent_spans, Span, SpanKind, SpanRecord};
