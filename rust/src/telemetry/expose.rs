//! Scrape-side telemetry: shard merging, snapshots, quantiles, and
//! the Prometheus-text / JSON exposition writers.
//!
//! Everything in this module allocates freely — it runs when someone
//! *reads* the metrics (CLI watcher, `--metrics-out`, trace capture),
//! never on the serving hot path.

use std::io;
use std::path::Path;
use std::sync::atomic::Ordering;

use super::registry::{self, Counter, Gauge, Hist, Registry};
use super::span::{recent_spans, SpanRecord};
use crate::util::json::Json;

/// Exposition metric-name prefix.
pub const PROM_PREFIX: &str = "bip_moe_";
/// `format` tag stamped into JSON snapshots.
pub const SNAPSHOT_FORMAT: &str = "bip-moe-metrics";
/// Snapshot schema version (also the trace telemetry-section version).
pub const SNAPSHOT_VERSION: u32 = 1;
/// Spans included in a JSON snapshot.
const SNAPSHOT_SPANS: usize = 32;

/// One histogram, merged across shards.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub name: &'static str,
    /// upper-inclusive bucket bounds; one implicit overflow bucket
    pub bounds: Vec<f64>,
    /// per-bucket counts, `bounds.len() + 1` entries
    pub counts: Vec<u64>,
    /// sum of observed values
    pub sum: f64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.counts
            .iter()
            .fold(0u64, |acc, &c| acc.saturating_add(c))
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    /// Quantile estimate by linear interpolation inside the covering
    /// bucket — exact to within that bucket's width (pinned by tests).
    /// Values are assumed non-negative (every registry histogram is);
    /// the overflow bucket clamps to the last bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 || self.bounds.is_empty() {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            let c = c as f64;
            if c > 0.0 && cum + c >= target {
                if i >= self.bounds.len() {
                    return self.bounds[self.bounds.len() - 1];
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = ((target - cum) / c).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            cum += c;
        }
        self.bounds[self.bounds.len() - 1]
    }

    /// Elementwise (saturating) merge of a same-shaped histogram —
    /// shard merging and snapshot aggregation both funnel here.
    /// Associative and commutative (pinned by tests).
    pub fn merge(&mut self, other: &HistSnapshot) {
        assert_eq!(self.name, other.name, "merging unrelated hists");
        assert_eq!(self.bounds, other.bounds);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a = a.saturating_add(*b);
        }
        self.sum += other.sum;
    }
}

/// A point-in-time view of a [`Registry`], shards already merged.
/// Indexing follows the enum discriminants (`snap.counters[c as
/// usize]`); use [`Snapshot::counter`] etc. for readable access.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// seconds since the process's first telemetry event
    pub elapsed_secs: f64,
    pub counters: Vec<u64>,
    pub gauges: Vec<f64>,
    pub hists: Vec<HistSnapshot>,
    /// cumulative routed tokens, `[layer][expert]`, trimmed to the
    /// active extent
    pub expert_tokens: Vec<Vec<u64>>,
    /// recent spans (global registry scrapes only), newest first
    pub spans: Vec<SpanRecord>,
}

impl Snapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g as usize]
    }

    pub fn hist(&self, h: Hist) -> &HistSnapshot {
        &self.hists[h as usize]
    }

    /// Counters that advanced since `prev`, as `(name, delta)`.
    pub fn counter_deltas(
        &self,
        prev: &Snapshot,
    ) -> Vec<(&'static str, u64)> {
        Counter::ALL
            .iter()
            .filter_map(|&c| {
                let d = self
                    .counter(c)
                    .saturating_sub(prev.counter(c));
                (d > 0).then(|| (c.name(), d))
            })
            .collect()
    }

    /// Fold `other` into `self`: counters/histograms/expert tokens
    /// accumulate (saturating); gauges keep `self`'s last-write-wins
    /// values; `elapsed_secs` takes the max. Associative and
    /// commutative on the accumulated fields (pinned by tests).
    pub fn merge(&mut self, other: &Snapshot) {
        self.elapsed_secs = self.elapsed_secs.max(other.elapsed_secs);
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a = a.saturating_add(*b);
        }
        for (h, o) in self.hists.iter_mut().zip(&other.hists) {
            h.merge(o);
        }
        let layers = self.expert_tokens.len().max(other.expert_tokens.len());
        let experts = self
            .expert_tokens
            .iter()
            .chain(&other.expert_tokens)
            .map(|r| r.len())
            .max()
            .unwrap_or(0);
        self.expert_tokens.resize(layers, Vec::new());
        for row in &mut self.expert_tokens {
            row.resize(experts, 0);
        }
        for (l, row) in other.expert_tokens.iter().enumerate() {
            for (e, &v) in row.iter().enumerate() {
                let cell = &mut self.expert_tokens[l][e];
                *cell = cell.saturating_add(v);
            }
        }
    }

    /// Prometheus text exposition (counters, gauges, labelled
    /// per-expert token counters, cumulative-`le` histograms).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        for c in Counter::ALL {
            let name = c.name();
            let _ = writeln!(
                out,
                "# HELP {PROM_PREFIX}{name} {}",
                c.help()
            );
            let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} counter");
            let _ = writeln!(
                out,
                "{PROM_PREFIX}{name} {}",
                self.counter(c)
            );
        }
        for g in Gauge::ALL {
            let name = g.name();
            let _ = writeln!(
                out,
                "# HELP {PROM_PREFIX}{name} {}",
                g.help()
            );
            let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} gauge");
            let _ =
                writeln!(out, "{PROM_PREFIX}{name} {}", self.gauge(g));
        }
        if !self.expert_tokens.is_empty() {
            let name = "router_expert_tokens_total";
            let _ = writeln!(
                out,
                "# HELP {PROM_PREFIX}{name} tokens routed per (layer, \
                 expert)"
            );
            let _ = writeln!(out, "# TYPE {PROM_PREFIX}{name} counter");
            for (l, row) in self.expert_tokens.iter().enumerate() {
                for (e, &v) in row.iter().enumerate() {
                    let _ = writeln!(
                        out,
                        "{PROM_PREFIX}{name}{{layer=\"{l}\",\
                         expert=\"{e}\"}} {v}"
                    );
                }
            }
        }
        for h in &self.hists {
            let name = h.name;
            let _ = writeln!(
                out,
                "# TYPE {PROM_PREFIX}{name} histogram"
            );
            let mut cum = 0u64;
            for (i, &le) in h.bounds.iter().enumerate() {
                cum = cum.saturating_add(h.counts[i]);
                let _ = writeln!(
                    out,
                    "{PROM_PREFIX}{name}_bucket{{le=\"{le}\"}} {cum}"
                );
            }
            let _ = writeln!(
                out,
                "{PROM_PREFIX}{name}_bucket{{le=\"+Inf\"}} {}",
                h.count()
            );
            let _ = writeln!(
                out,
                "{PROM_PREFIX}{name}_sum {}",
                h.sum
            );
            let _ = writeln!(
                out,
                "{PROM_PREFIX}{name}_count {}",
                h.count()
            );
        }
        out
    }

    /// JSON snapshot (the `--metrics-out` / `metrics check` format).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            Counter::ALL
                .iter()
                .map(|&c| {
                    (
                        c.name().to_string(),
                        Json::Num(self.counter(c) as f64),
                    )
                })
                .collect(),
        );
        let gauges = Json::Obj(
            Gauge::ALL
                .iter()
                .map(|&g| {
                    (g.name().to_string(), Json::Num(self.gauge(g)))
                })
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|h| {
                    (
                        h.name.to_string(),
                        Json::obj(vec![
                            ("bounds", Json::from_f64s(&h.bounds)),
                            (
                                "counts",
                                Json::Arr(
                                    h.counts
                                        .iter()
                                        .map(|&c| Json::Num(c as f64))
                                        .collect(),
                                ),
                            ),
                            ("sum", Json::Num(h.sum)),
                            ("count", Json::Num(h.count() as f64)),
                            ("p50", Json::Num(h.quantile(0.5))),
                            ("p99", Json::Num(h.quantile(0.99))),
                        ]),
                    )
                })
                .collect(),
        );
        let expert_tokens = Json::Arr(
            self.expert_tokens
                .iter()
                .map(|row| {
                    Json::Arr(
                        row.iter()
                            .map(|&v| Json::Num(v as f64))
                            .collect(),
                    )
                })
                .collect(),
        );
        let spans = Json::Arr(
            self.spans
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("kind", Json::Str(s.kind.name().into())),
                        ("secs", Json::Num(s.secs)),
                        ("at_secs", Json::Num(s.at_secs)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("format", Json::Str(SNAPSHOT_FORMAT.into())),
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("crate_version", Json::Str(crate::VERSION.into())),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
            ("expert_tokens", expert_tokens),
            ("spans", spans),
        ])
    }

    /// Write this snapshot to `path`: Prometheus text when the
    /// extension is `.prom`/`.txt`, JSON otherwise.
    pub fn write(&self, path: &Path) -> io::Result<()> {
        let prom = matches!(
            path.extension().and_then(|e| e.to_str()),
            Some("prom") | Some("txt")
        );
        let body = if prom {
            self.to_prometheus()
        } else {
            self.to_json().to_string()
        };
        std::fs::write(path, body)
    }
}

/// Merge a registry's shards into a [`Snapshot`]. Recent spans ride
/// along only when scraping the process-global registry (the span
/// ring is global; attaching it to a private test registry would
/// leak cross-test noise).
pub fn scrape(reg: &Registry) -> Snapshot {
    let mut counters = vec![0u64; Counter::ALL.len()];
    let mut hists: Vec<HistSnapshot> = Hist::ALL
        .iter()
        .map(|&h| HistSnapshot {
            name: h.name(),
            bounds: h.bounds().to_vec(),
            counts: vec![0u64; h.bounds().len() + 1],
            sum: 0.0,
        })
        .collect();
    for shard in &reg.shards {
        for (i, cell) in shard.counters.iter().enumerate() {
            counters[i] = counters[i]
                .saturating_add(cell.load(Ordering::Relaxed));
        }
        for (hi, h) in hists.iter_mut().enumerate() {
            for (b, cell) in h
                .counts
                .iter_mut()
                .zip(shard.hist_counts[hi].iter())
            {
                *b = b.saturating_add(cell.load(Ordering::Relaxed));
            }
            h.sum += f64::from_bits(
                shard.hist_sum_bits[hi].load(Ordering::Relaxed),
            );
        }
    }
    let gauges: Vec<f64> = reg
        .gauges
        .iter()
        .map(|g| f64::from_bits(g.load(Ordering::Relaxed)))
        .collect();
    // trim the bounded (layer, expert) grid to its active extent
    let mut layers = 0usize;
    let mut experts = 0usize;
    for (l, row) in reg.expert_tokens.iter().enumerate() {
        for (e, cell) in row.iter().enumerate() {
            if cell.load(Ordering::Relaxed) > 0 {
                layers = layers.max(l + 1);
                experts = experts.max(e + 1);
            }
        }
    }
    let expert_tokens: Vec<Vec<u64>> = reg.expert_tokens[..layers]
        .iter()
        .map(|row| {
            row[..experts]
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect()
        })
        .collect();
    let spans = if std::ptr::eq(reg, registry::global()) {
        recent_spans(SNAPSHOT_SPANS)
    } else {
        Vec::new()
    };
    Snapshot {
        elapsed_secs: super::span::elapsed_secs(),
        counters,
        gauges,
        hists,
        expert_tokens,
        spans,
    }
}

/// Scrape the global registry into flat `(name, value)` pairs —
/// counters then gauges. This is the payload the trace recorder
/// embeds (telemetry section) and replay diffs against.
pub fn scrape_named() -> Vec<(String, f64)> {
    let snap = scrape(registry::global());
    Counter::ALL
        .iter()
        .map(|&c| (c.name().to_string(), snap.counter(c) as f64))
        .chain(
            Gauge::ALL
                .iter()
                .map(|&g| (g.name().to_string(), snap.gauge(g))),
        )
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(counts: &[u64], sum: f64) -> HistSnapshot {
        let bounds = Hist::SolverMaxVio.bounds().to_vec();
        assert_eq!(counts.len(), bounds.len() + 1);
        HistSnapshot {
            name: Hist::SolverMaxVio.name(),
            bounds,
            counts: counts.to_vec(),
            sum,
        }
    }

    #[test]
    fn quantiles_are_within_one_bucket_width() {
        // 1000 uniform observations on (0, 1]: the estimate for any
        // quantile must land within the width of the covering bucket
        let reg = Registry::new();
        for k in 1..=1000 {
            reg.hist_observe(Hist::SolverMaxVio, k as f64 / 1000.0);
        }
        let snap = scrape(&reg);
        let h = snap.hist(Hist::SolverMaxVio);
        assert_eq!(h.count(), 1000);
        for q in [0.05, 0.1, 0.25, 0.5, 0.9, 0.99] {
            let truth = q; // uniform on (0, 1]
            let est = h.quantile(q);
            let bi = h
                .bounds
                .iter()
                .position(|&b| truth <= b)
                .unwrap();
            let lo = if bi == 0 { 0.0 } else { h.bounds[bi - 1] };
            let width = h.bounds[bi] - lo;
            assert!(
                (est - truth).abs() <= width + 1e-9,
                "q={q}: est {est} vs {truth} (width {width})"
            );
        }
    }

    #[test]
    fn quantile_handles_empty_and_overflow() {
        let empty = filled(&[0; 10], 0.0);
        assert_eq!(empty.quantile(0.5), 0.0);
        // everything in the overflow bucket clamps to the last bound
        let mut over = filled(&[0; 10], 0.0);
        *over.counts.last_mut().unwrap() = 7;
        assert_eq!(over.quantile(0.5), *over.bounds.last().unwrap());
    }

    #[test]
    fn hist_merge_is_commutative_and_associative() {
        // integer-valued sums keep f64 addition exact, so the merged
        // snapshots compare bit-equal in every association order
        let a = filled(&[1, 0, 3, 0, 0, 2, 0, 0, 0, 4], 9.0);
        let b = filled(&[0, 5, 0, 0, 1, 0, 0, 2, 0, 0], 21.0);
        let c = filled(&[2, 2, 2, 2, 2, 2, 2, 2, 2, 2], 14.0);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must commute");

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must associate");
    }

    #[test]
    fn hist_merge_saturates() {
        let mut a = filled(&[u64::MAX - 1, 0, 0, 0, 0, 0, 0, 0, 0, 0], 1.0);
        let b = filled(&[5, 0, 0, 0, 0, 0, 0, 0, 0, 0], 1.0);
        a.merge(&b);
        assert_eq!(a.counts[0], u64::MAX);
        assert_eq!(a.count(), u64::MAX);
    }

    #[test]
    fn snapshot_merge_accumulates_counters_and_experts() {
        let r1 = Registry::new();
        let r2 = Registry::new();
        r1.counter_add(Counter::RouterBatches, 3);
        r1.expert_tokens_add(0, &[1, 2]);
        r2.counter_add(Counter::RouterBatches, 4);
        r2.counter_add(Counter::SolverSolves, 1);
        r2.expert_tokens_add(1, &[0, 0, 7]);
        let mut s1 = scrape(&r1);
        let s2 = scrape(&r2);
        let mut s21 = s2.clone();
        s1.merge(&s2);
        s21.merge(&scrape(&r1));
        assert_eq!(s1.counter(Counter::RouterBatches), 7);
        assert_eq!(s1.counter(Counter::SolverSolves), 1);
        assert_eq!(s1.expert_tokens[1][2], 7);
        assert_eq!(s1.expert_tokens[0][1], 2);
        assert_eq!(
            s1.counters, s21.counters,
            "snapshot merge must commute"
        );
        assert_eq!(s1.expert_tokens, s21.expert_tokens);
    }

    #[test]
    fn counter_deltas_report_only_movement() {
        let reg = Registry::new();
        reg.counter_add(Counter::RouterBatches, 2);
        let before = scrape(&reg);
        reg.counter_add(Counter::RouterBatches, 3);
        reg.counter_add(Counter::ServeShed, 1);
        let after = scrape(&reg);
        let deltas = after.counter_deltas(&before);
        assert_eq!(
            deltas,
            vec![
                (Counter::RouterBatches.name(), 3),
                (Counter::ServeShed.name(), 1)
            ]
        );
    }

    #[test]
    fn prometheus_text_has_the_expected_series() {
        let reg = Registry::new();
        reg.counter_add(Counter::RouterTokens, 640);
        reg.gauge_set(Gauge::RouterExperts, 16.0);
        reg.hist_observe(Hist::RouteBatchSeconds, 33e-6);
        reg.expert_tokens_add(0, &[10, 0, 5]);
        let text = scrape(&reg).to_prometheus();
        assert!(text.contains("# TYPE bip_moe_router_tokens_total counter"));
        assert!(text.contains("bip_moe_router_tokens_total 640"));
        assert!(text.contains("bip_moe_router_experts 16"));
        assert!(text.contains(
            "bip_moe_router_expert_tokens_total{layer=\"0\",\
             expert=\"2\"} 5"
        ));
        assert!(text.contains(
            "bip_moe_route_batch_seconds_bucket{le=\"+Inf\"} 1"
        ));
        assert!(text.contains("bip_moe_route_batch_seconds_count 1"));
    }

    #[test]
    fn json_snapshot_round_trips_through_the_parser() {
        let reg = Registry::new();
        reg.counter_add(Counter::RouterBatches, 12);
        reg.counter_add(Counter::RouterTokens, 768);
        reg.gauge_set(Gauge::RouterLayers, 4.0);
        reg.hist_observe(Hist::SolverSolveSeconds, 1.5e-4);
        let json = scrape(&reg).to_json().to_string();
        let doc = Json::parse(&json).expect("snapshot must parse");
        assert_eq!(
            doc.path("format").and_then(|j| j.as_str()),
            Some(SNAPSHOT_FORMAT)
        );
        assert_eq!(
            doc.path("counters.router_batches_total")
                .and_then(|j| j.as_f64()),
            Some(12.0)
        );
        assert_eq!(
            doc.path("gauges.router_layers").and_then(|j| j.as_f64()),
            Some(4.0)
        );
        assert_eq!(
            doc.path("histograms.solver_solve_seconds.count")
                .and_then(|j| j.as_f64()),
            Some(1.0)
        );
    }

    #[test]
    fn scrape_named_covers_every_counter_and_gauge() {
        let named = scrape_named();
        assert_eq!(
            named.len(),
            Counter::ALL.len() + Gauge::ALL.len()
        );
        assert!(named
            .iter()
            .any(|(n, _)| n == "router_batches_total"));
        assert!(named.iter().any(|(n, _)| n == "solver_last_maxvio"));
    }
}
