//! RAII span timers and the bounded recent-span ring.
//!
//! A [`Span`] is constructed at the top of an instrumented scope and,
//! on drop, feeds its wall time into the matching registry histogram
//! and into a fixed global ring of the most recent [`RING_SLOTS`]
//! spans. Nothing on this path allocates: `Instant::now` is a clock
//! read, the histogram write is a sharded atomic RMW
//! ([`crate::telemetry::registry`]), and each ring slot is a pair of
//! pre-existing atomics written with relaxed stores. The ring is
//! intentionally lossy under contention (a reader can observe a slot
//! mid-overwrite); it exists for "what just happened" debugging in
//! snapshots and the `bip-moe metrics` watcher, not for accounting —
//! the histograms are the accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use super::registry::{self, Hist};

/// Capacity of the recent-span ring.
pub const RING_SLOTS: usize = 256;

/// The instrumented scopes. The discriminant is packed into ring
/// slots, so keep it within `u8`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// `ServingRouter::route_batch[_into]` — one micro-batch
    RouteBatch = 0,
    /// one Algorithm 1 per-batch solve (`routing::Bip`)
    SolverSolve = 1,
    /// one replica's route job inside `ReplicaSet::route_parallel`
    ReplicaDispatch = 2,
    /// one training step (`train::TrainDriver`)
    TrainStep = 3,
}

const N_KINDS: usize = 4;

impl SpanKind {
    pub const ALL: [SpanKind; N_KINDS] = [
        SpanKind::RouteBatch,
        SpanKind::SolverSolve,
        SpanKind::ReplicaDispatch,
        SpanKind::TrainStep,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::RouteBatch => "route_batch",
            SpanKind::SolverSolve => "solver_solve",
            SpanKind::ReplicaDispatch => "replica_dispatch",
            SpanKind::TrainStep => "train_step",
        }
    }

    /// The registry histogram this span's duration feeds.
    pub fn hist(self) -> Hist {
        match self {
            SpanKind::RouteBatch => Hist::RouteBatchSeconds,
            SpanKind::SolverSolve => Hist::SolverSolveSeconds,
            SpanKind::ReplicaDispatch => Hist::ReplicaDispatchSeconds,
            SpanKind::TrainStep => Hist::TrainStepSeconds,
        }
    }

    fn from_u8(v: u8) -> Option<SpanKind> {
        Self::ALL.into_iter().find(|k| *k as u8 == v)
    }
}

/// RAII timer: created with [`Span::enter`], records on drop. Bind it
/// (`let _span = Span::enter(..)`) so it lives to the end of scope.
pub struct Span {
    kind: SpanKind,
    start: Instant,
    live: bool,
}

impl Span {
    pub fn enter(kind: SpanKind) -> Span {
        Span { kind, start: Instant::now(), live: registry::enabled() }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let d = self.start.elapsed();
        registry::hist_observe(self.kind.hist(), d.as_secs_f64());
        ring_record(self.kind, d);
    }
}

/// One completed span as read back out of the ring.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    pub kind: SpanKind,
    pub secs: f64,
    /// process-monotonic end time, seconds since [`epoch`]
    pub at_secs: f64,
}

// Ring storage: `kind_dur[i]` packs the span kind into the top 8 bits
// and the duration (ns, clamped to 2^56-1) below; `at[i]` is the end
// time in ns since the telemetry epoch. Slot 0 of `at` doubles as the
// "never written" sentinel via the parallel head counter.
const ZERO: AtomicU64 = AtomicU64::new(0);
static RING_KIND_DUR: [AtomicU64; RING_SLOTS] = [ZERO; RING_SLOTS];
static RING_AT: [AtomicU64; RING_SLOTS] = [ZERO; RING_SLOTS];
static RING_HEAD: AtomicU64 = AtomicU64::new(0);

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Seconds since the first telemetry event of the process.
pub fn elapsed_secs() -> f64 {
    epoch().elapsed().as_secs_f64()
}

const DUR_MASK: u64 = (1 << 56) - 1;

fn ring_record(kind: SpanKind, dur: Duration) {
    let slot =
        (RING_HEAD.fetch_add(1, Ordering::Relaxed) as usize) % RING_SLOTS;
    let ns = (dur.as_nanos() as u64).min(DUR_MASK);
    let at = (epoch().elapsed().as_nanos() as u64).min(DUR_MASK);
    RING_KIND_DUR[slot]
        .store(((kind as u64) << 56) | ns, Ordering::Relaxed);
    RING_AT[slot].store(at, Ordering::Relaxed);
}

/// The most recent `max` completed spans, newest first. Allocates (it
/// is a scrape-side call) and tolerates torn slots under concurrency.
pub fn recent_spans(max: usize) -> Vec<SpanRecord> {
    let head = RING_HEAD.load(Ordering::Relaxed);
    let filled = (head as usize).min(RING_SLOTS);
    let take = max.min(filled);
    let mut out = Vec::with_capacity(take);
    for back in 1..=take {
        let slot = ((head as usize) + RING_SLOTS - back) % RING_SLOTS;
        let packed = RING_KIND_DUR[slot].load(Ordering::Relaxed);
        let Some(kind) = SpanKind::from_u8((packed >> 56) as u8) else {
            continue;
        };
        out.push(SpanRecord {
            kind,
            secs: (packed & DUR_MASK) as f64 * 1e-9,
            at_secs: RING_AT[slot].load(Ordering::Relaxed) as f64
                * 1e-9,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_kinds_pack_into_a_byte_and_back() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::from_u8(k as u8), Some(k));
        }
        assert_eq!(SpanKind::from_u8(200), None);
    }

    #[test]
    fn dropped_spans_land_in_the_ring_newest_first() {
        {
            let _a = Span::enter(SpanKind::TrainStep);
        }
        {
            let _b = Span::enter(SpanKind::SolverSolve);
        }
        let recent = recent_spans(RING_SLOTS);
        // other tests run concurrently against the same global ring,
        // so only assert our two spans both exist somewhere recent
        assert!(recent
            .iter()
            .any(|s| s.kind == SpanKind::SolverSolve));
        assert!(recent.iter().any(|s| s.kind == SpanKind::TrainStep));
        for s in &recent {
            assert!(s.secs >= 0.0 && s.at_secs >= 0.0);
        }
    }

    #[test]
    fn ring_read_is_bounded_by_both_max_and_capacity() {
        for _ in 0..4 {
            let _s = Span::enter(SpanKind::RouteBatch);
        }
        assert!(recent_spans(2).len() <= 2);
        assert!(recent_spans(10_000).len() <= RING_SLOTS);
    }
}
