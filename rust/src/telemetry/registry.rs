//! The static metrics registry: counters, gauges and fixed-bucket
//! histograms with fully preallocated storage.
//!
//! Ownership rules (the reason the PR-5 zero-allocation gate keeps
//! passing with telemetry on):
//!
//! * every metric is a member of a closed enum ([`Counter`], [`Gauge`],
//!   [`Hist`]) with a compile-time index — registration is the enum
//!   definition, so the write path never touches a map or a string;
//! * all storage lives in one `static` [`Registry`] built by a `const
//!   fn` — no lazy heap, no `OnceLock<Box<_>>`, nothing to allocate at
//!   first use;
//! * counters and histogram cells are sharded over [`N_SHARDS`]
//!   preallocated shards; a writer thread picks its shard once through
//!   a const-initialized `thread_local!` cell (no TLS destructor, no
//!   lazy allocation) and every write is a relaxed atomic RMW on its
//!   own shard — scrapes merge the shards off the hot path;
//! * gauges are last-write-wins `f64`-bit stores and live un-sharded;
//! * counter and histogram adds *saturate* at `u64::MAX` instead of
//!   wrapping or panicking (pinned by tests) — a telemetry cell must
//!   never be able to take the serving loop down;
//! * everything early-returns when the registry is disabled
//!   ([`set_enabled`]) — the compiled-out baseline `bench_hotpath`
//!   prices the registry against.
//!
//! Per-layer per-expert routed-token counters get dedicated bounded
//! storage (`MAX_LAYERS` x `MAX_EXPERTS`); layers or experts beyond the
//! bound are silently not tracked rather than allocated for.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Counter shards merged on scrape. 16 is comfortably above the
/// serving pool sizes the repo runs (`--threads` defaults to 1-4).
pub const N_SHARDS: usize = 16;
/// Histogram storage slots per shard: max bucket-bound count + 1
/// overflow bucket (asserted against every [`Hist::bounds`] by tests).
pub const HIST_SLOTS: usize = 12;
/// Per-layer per-expert token counters exist for this many layers ...
pub const MAX_LAYERS: usize = 8;
/// ... and this many experts per layer.
pub const MAX_EXPERTS: usize = 64;

/// Monotonic event counters. `*Total` naming follows the Prometheus
/// convention; [`Counter::name`] is the exposition name (exported with
/// a `bip_moe_` prefix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// micro-batches routed (`ServingRouter`, both routing paths)
    RouterBatches = 0,
    /// tokens routed
    RouterTokens = 1,
    /// capacity-overflow reroutes
    RouterOverflow = 2,
    /// degraded slots (no expert had room)
    RouterDegraded = 3,
    /// sampled (token, layer) pairs whose enforced top-K kept the
    /// gate's argmax expert
    RouterTopkAgree = 4,
    /// sampled (token, layer) pairs (the agreement denominator)
    RouterTopkSampled = 5,
    /// Algorithm 1 per-batch solves
    SolverSolves = 6,
    /// dual iterations actually run (fixed-T or adaptive)
    SolverIterations = 7,
    /// expert columns calm (lazily re-evaluated) at adaptive-solve end
    SolverCalmColumns = 8,
    /// offered requests shed upstream of the queue (predictive gate)
    ServeShed = 9,
    /// micro-batches dispatched to replicas
    ReplicaDispatches = 10,
    /// replica merge-syncs fired
    ReplicaSyncs = 11,
    /// walk-forward forecast samples scored by `forecast eval`
    ForecastEvalSamples = 12,
    /// training steps driven
    TrainSteps = 13,
    /// causal events recorded into the obs event ring
    ObsEvents = 14,
    /// typed anomaly alerts raised by the obs detector
    ObsAlerts = 15,
    /// incident files dumped by the obs flight recorder
    ObsIncidents = 16,
    /// call-path frames recorded by the hierarchical profiler
    ProfFrames = 17,
    /// profiler frames dropped (stack deeper than `prof::MAX_DEPTH`
    /// or a path table shard ran out of slots)
    ProfStackOverflow = 18,
}

const N_COUNTERS: usize = 19;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::RouterBatches,
        Counter::RouterTokens,
        Counter::RouterOverflow,
        Counter::RouterDegraded,
        Counter::RouterTopkAgree,
        Counter::RouterTopkSampled,
        Counter::SolverSolves,
        Counter::SolverIterations,
        Counter::SolverCalmColumns,
        Counter::ServeShed,
        Counter::ReplicaDispatches,
        Counter::ReplicaSyncs,
        Counter::ForecastEvalSamples,
        Counter::TrainSteps,
        Counter::ObsEvents,
        Counter::ObsAlerts,
        Counter::ObsIncidents,
        Counter::ProfFrames,
        Counter::ProfStackOverflow,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::RouterBatches => "router_batches_total",
            Counter::RouterTokens => "router_tokens_total",
            Counter::RouterOverflow => "router_overflow_total",
            Counter::RouterDegraded => "router_degraded_total",
            Counter::RouterTopkAgree => "router_topk_agree_total",
            Counter::RouterTopkSampled => "router_topk_sampled_total",
            Counter::SolverSolves => "solver_solves_total",
            Counter::SolverIterations => "solver_iterations_total",
            Counter::SolverCalmColumns => "solver_calm_columns_total",
            Counter::ServeShed => "serve_shed_total",
            Counter::ReplicaDispatches => "replica_dispatches_total",
            Counter::ReplicaSyncs => "replica_syncs_total",
            Counter::ForecastEvalSamples => {
                "forecast_eval_samples_total"
            }
            Counter::TrainSteps => "train_steps_total",
            Counter::ObsEvents => "obs_events_total",
            Counter::ObsAlerts => "obs_alerts_total",
            Counter::ObsIncidents => "obs_incidents_total",
            Counter::ProfFrames => "prof_frames_total",
            Counter::ProfStackOverflow => "prof_stack_overflow_total",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Counter::RouterBatches => "micro-batches routed",
            Counter::RouterTokens => "tokens routed",
            Counter::RouterOverflow => "capacity-overflow reroutes",
            Counter::RouterDegraded => {
                "token slots degraded (no expert had room)"
            }
            Counter::RouterTopkAgree => {
                "sampled slots whose enforced top-K kept the gate argmax"
            }
            Counter::RouterTopkSampled => {
                "slots sampled for top-K agreement"
            }
            Counter::SolverSolves => "Algorithm 1 per-batch solves",
            Counter::SolverIterations => "dual iterations run",
            Counter::SolverCalmColumns => {
                "calm (lazily re-evaluated) columns at solve end"
            }
            Counter::ServeShed => {
                "requests shed upstream of the admission queue"
            }
            Counter::ReplicaDispatches => {
                "micro-batches dispatched to replicas"
            }
            Counter::ReplicaSyncs => "replica merge-syncs",
            Counter::ForecastEvalSamples => {
                "walk-forward forecast samples scored"
            }
            Counter::TrainSteps => "training steps driven",
            Counter::ObsEvents => {
                "causal events recorded into the obs ring"
            }
            Counter::ObsAlerts => "anomaly alerts raised",
            Counter::ObsIncidents => "incident files dumped",
            Counter::ProfFrames => {
                "call-path frames recorded by the profiler"
            }
            Counter::ProfStackOverflow => {
                "profiler frames dropped (stack depth or table full)"
            }
        }
    }
}

/// Last-write-wins instantaneous values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// the last routed batch's layer-mean MaxVio
    RouterLastBatchVio = 0,
    /// best primal MaxVio of the last adaptive solve
    SolverLastMaxVio = 1,
    /// iterations the last solve ran
    SolverLastIters = 2,
    /// admission queue depth after the last ingest sweep
    ServeQueueDepth = 3,
    /// mean-abs dual/bias divergence entering the last merge-sync
    ReplicaLastSyncDivergence = 4,
    /// pooled MAE of the last `forecast eval` (shortest horizon)
    ForecastLastMae = 5,
    /// router gate depth (layers), set at router construction
    RouterLayers = 6,
    /// router gate width (experts), set at router construction
    RouterExperts = 7,
    /// autoscaler's active replica count after the last decision
    AutoscaleReplicas = 8,
    /// last training step's global MaxVio
    TrainLastMaxVio = 9,
    /// live records in the obs event ring (saturates at capacity)
    ObsEventRingOccupancy = 10,
    /// detector's current routing-collapse concentration score
    ObsCollapseScore = 11,
}

const N_GAUGES: usize = 12;

impl Gauge {
    pub const ALL: [Gauge; N_GAUGES] = [
        Gauge::RouterLastBatchVio,
        Gauge::SolverLastMaxVio,
        Gauge::SolverLastIters,
        Gauge::ServeQueueDepth,
        Gauge::ReplicaLastSyncDivergence,
        Gauge::ForecastLastMae,
        Gauge::RouterLayers,
        Gauge::RouterExperts,
        Gauge::AutoscaleReplicas,
        Gauge::TrainLastMaxVio,
        Gauge::ObsEventRingOccupancy,
        Gauge::ObsCollapseScore,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::RouterLastBatchVio => "router_last_batch_vio",
            Gauge::SolverLastMaxVio => "solver_last_maxvio",
            Gauge::SolverLastIters => "solver_last_iters",
            Gauge::ServeQueueDepth => "serve_queue_depth",
            Gauge::ReplicaLastSyncDivergence => {
                "replica_last_sync_divergence"
            }
            Gauge::ForecastLastMae => "forecast_last_mae",
            Gauge::RouterLayers => "router_layers",
            Gauge::RouterExperts => "router_experts",
            Gauge::AutoscaleReplicas => "autoscale_active_replicas",
            Gauge::TrainLastMaxVio => "train_last_maxvio",
            Gauge::ObsEventRingOccupancy => {
                "obs_event_ring_occupancy"
            }
            Gauge::ObsCollapseScore => "obs_collapse_score",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Gauge::RouterLastBatchVio => {
                "layer-mean MaxVio of the last routed batch"
            }
            Gauge::SolverLastMaxVio => {
                "best primal MaxVio of the last adaptive solve"
            }
            Gauge::SolverLastIters => "iterations the last solve ran",
            Gauge::ServeQueueDepth => "admission queue depth",
            Gauge::ReplicaLastSyncDivergence => {
                "state divergence entering the last merge-sync"
            }
            Gauge::ForecastLastMae => "last forecast-eval pooled MAE",
            Gauge::RouterLayers => "router gate depth (layers)",
            Gauge::RouterExperts => "router gate width (experts)",
            Gauge::AutoscaleReplicas => "active replicas",
            Gauge::TrainLastMaxVio => "last training-step MaxVio",
            Gauge::ObsEventRingOccupancy => {
                "live records in the obs event ring"
            }
            Gauge::ObsCollapseScore => {
                "detector routing-collapse concentration score"
            }
        }
    }
}

/// Exponential-ish wall-time buckets, 1µs .. 1s (seconds).
pub const TIME_BUCKETS: [f64; 11] = [
    1e-6, 4e-6, 16e-6, 64e-6, 256e-6, 1e-3, 4e-3, 16e-3, 64e-3, 0.25,
    1.0,
];
/// Power-of-two iteration-count buckets.
pub const ITER_BUCKETS: [f64; 8] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
/// MaxVio buckets spanning balanced (0.01) to pathological (5.0).
pub const VIO_BUCKETS: [f64; 9] =
    [0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0];
/// Forecast absolute-error buckets (load fractions).
pub const ERR_BUCKETS: [f64; 9] =
    [1e-4, 3e-4, 1e-3, 3e-3, 0.01, 0.03, 0.1, 0.3, 1.0];

/// Fixed-bucket histograms. Bounds are upper-inclusive per bucket with
/// one implicit overflow bucket — standard Prometheus `le` semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// `ServingRouter::route_batch_into` wall time (span-fed)
    RouteBatchSeconds = 0,
    /// Algorithm 1 per-batch solve wall time (span-fed)
    SolverSolveSeconds = 1,
    /// per-replica dispatch (route job) wall time (span-fed)
    ReplicaDispatchSeconds = 2,
    /// dual iterations per solve
    SolverItersPerSolve = 3,
    /// best primal MaxVio per adaptive solve
    SolverMaxVio = 4,
    /// forecast absolute error per eval sample batch
    ForecastAbsErr = 5,
    /// training step wall time
    TrainStepSeconds = 6,
}

const N_HISTS: usize = 7;

impl Hist {
    pub const ALL: [Hist; N_HISTS] = [
        Hist::RouteBatchSeconds,
        Hist::SolverSolveSeconds,
        Hist::ReplicaDispatchSeconds,
        Hist::SolverItersPerSolve,
        Hist::SolverMaxVio,
        Hist::ForecastAbsErr,
        Hist::TrainStepSeconds,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Hist::RouteBatchSeconds => "route_batch_seconds",
            Hist::SolverSolveSeconds => "solver_solve_seconds",
            Hist::ReplicaDispatchSeconds => {
                "replica_dispatch_seconds"
            }
            Hist::SolverItersPerSolve => "solver_iters_per_solve",
            Hist::SolverMaxVio => "solver_maxvio",
            Hist::ForecastAbsErr => "forecast_abs_err",
            Hist::TrainStepSeconds => "train_step_seconds",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Hist::RouteBatchSeconds => {
                "route_batch_into wall time per micro-batch"
            }
            Hist::SolverSolveSeconds => {
                "Algorithm 1 solve wall time per batch"
            }
            Hist::ReplicaDispatchSeconds => {
                "per-replica dispatch wall time"
            }
            Hist::SolverItersPerSolve => "dual iterations per solve",
            Hist::SolverMaxVio => "best MaxVio per adaptive solve",
            Hist::ForecastAbsErr => "forecast absolute error",
            Hist::TrainStepSeconds => "training step wall time",
        }
    }

    pub fn bounds(self) -> &'static [f64] {
        match self {
            Hist::RouteBatchSeconds
            | Hist::SolverSolveSeconds
            | Hist::ReplicaDispatchSeconds
            | Hist::TrainStepSeconds => &TIME_BUCKETS,
            Hist::SolverItersPerSolve => &ITER_BUCKETS,
            Hist::SolverMaxVio => &VIO_BUCKETS,
            Hist::ForecastAbsErr => &ERR_BUCKETS,
        }
    }
}

/// One write shard: counters plus histogram cells.
pub(crate) struct Shard {
    pub(crate) counters: [AtomicU64; N_COUNTERS],
    pub(crate) hist_counts: [[AtomicU64; HIST_SLOTS]; N_HISTS],
    /// histogram value sums as `f64` bit patterns (CAS-added)
    pub(crate) hist_sum_bits: [AtomicU64; N_HISTS],
}

impl Shard {
    const fn new() -> Shard {
        const Z: AtomicU64 = AtomicU64::new(0);
        const ROW: [AtomicU64; HIST_SLOTS] = [Z; HIST_SLOTS];
        Shard {
            counters: [Z; N_COUNTERS],
            hist_counts: [ROW; N_HISTS],
            hist_sum_bits: [Z; N_HISTS],
        }
    }
}

/// The registry. One `static` instance ([`global`]) backs the whole
/// crate; tests build private instances to avoid cross-test bleed.
pub struct Registry {
    enabled: AtomicBool,
    pub(crate) shards: [Shard; N_SHARDS],
    /// `f64` bit patterns, last write wins
    pub(crate) gauges: [AtomicU64; N_GAUGES],
    /// cumulative routed tokens per (layer, expert), bounded
    pub(crate) expert_tokens: [[AtomicU64; MAX_EXPERTS]; MAX_LAYERS],
}

impl Registry {
    pub const fn new() -> Registry {
        const S: Shard = Shard::new();
        const Z: AtomicU64 = AtomicU64::new(0);
        const EROW: [AtomicU64; MAX_EXPERTS] = [Z; MAX_EXPERTS];
        Registry {
            enabled: AtomicBool::new(true),
            shards: [S; N_SHARDS],
            gauges: [Z; N_GAUGES],
            expert_tokens: [EROW; MAX_LAYERS],
        }
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    // HOT: called from the serving/solver hot path — sharded atomics
    // only, no locks
    /// Saturating counter increment on this thread's shard.
    pub fn counter_add(&self, c: Counter, n: u64) {
        if !self.is_enabled() || n == 0 {
            return;
        }
        saturating_add(
            &self.shards[shard_index()].counters[c as usize],
            n,
        );
    }

    // HOT: called from the serving/solver hot path — one relaxed store
    /// Last-write-wins gauge store.
    pub fn gauge_set(&self, g: Gauge, v: f64) {
        if !self.is_enabled() {
            return;
        }
        self.gauges[g as usize].store(v.to_bits(), Ordering::Relaxed);
    }

    // HOT: span-exit path — bounded scan plus sharded atomics, no locks
    /// One histogram observation: linear scan over <= [`HIST_SLOTS`]
    /// bounds (cheaper than a branchy binary search at these sizes),
    /// saturating bucket increment, CAS-added sum.
    pub fn hist_observe(&self, h: Hist, v: f64) {
        if !self.is_enabled() {
            return;
        }
        let bounds = h.bounds();
        let mut i = 0usize;
        while i < bounds.len() && v > bounds[i] {
            i += 1;
        }
        let shard = &self.shards[shard_index()];
        saturating_add(&shard.hist_counts[h as usize][i], 1);
        f64_add(&shard.hist_sum_bits[h as usize], v);
    }

    /// Accumulate one layer's per-expert batch loads into the bounded
    /// (layer, expert) token counters; out-of-bound layers/experts are
    /// silently not tracked (never allocated for).
    pub fn expert_tokens_add(&self, layer: usize, loads: &[u32]) {
        if !self.is_enabled() || layer >= MAX_LAYERS {
            return;
        }
        let row = &self.expert_tokens[layer];
        for (e, &c) in loads.iter().take(MAX_EXPERTS).enumerate() {
            if c > 0 {
                saturating_add(&row[e], c as u64);
            }
        }
    }

    /// As [`Registry::expert_tokens_add`], for the router's native
    /// `f32` load rows (integral counts stored as floats).
    pub fn expert_tokens_add_f32(&self, layer: usize, loads: &[f32]) {
        if !self.is_enabled() || layer >= MAX_LAYERS {
            return;
        }
        let row = &self.expert_tokens[layer];
        for (e, &c) in loads.iter().take(MAX_EXPERTS).enumerate() {
            if c > 0.0 {
                saturating_add(&row[e], c as u64);
            }
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// Saturating atomic add: sticks at `u64::MAX`, never wraps or panics.
fn saturating_add(cell: &AtomicU64, n: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = cur.saturating_add(n);
        if next == cur {
            return; // already saturated
        }
        match cell.compare_exchange_weak(
            cur,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// CAS-loop `f64` accumulate over a bit-pattern cell.
fn f64_add(bits: &AtomicU64, v: f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match bits.compare_exchange_weak(
            cur,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

static GLOBAL: Registry = Registry::new();
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's shard index; const-initialized (no lazy heap, no
    /// TLS destructor) and assigned round-robin on first use.
    static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
}

pub(crate) fn shard_index() -> usize {
    SHARD.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % N_SHARDS;
        s.set(v);
        v
    })
}

/// The process-wide registry every instrumentation site writes to.
pub fn global() -> &'static Registry {
    &GLOBAL
}

/// Enable/disable the global registry at runtime (the
/// `bench_hotpath` telemetry-overhead section toggles this).
pub fn set_enabled(on: bool) {
    GLOBAL.set_enabled(on);
}

pub fn enabled() -> bool {
    GLOBAL.is_enabled()
}

// HOT: hot-path entry point for counters (lint root)
/// [`Registry::counter_add`] on the global registry.
pub fn counter_add(c: Counter, n: u64) {
    GLOBAL.counter_add(c, n);
}

// HOT: hot-path entry point for gauges (lint root)
/// [`Registry::gauge_set`] on the global registry.
pub fn gauge_set(g: Gauge, v: f64) {
    GLOBAL.gauge_set(g, v);
}

// HOT: hot-path entry point for histograms (lint root)
/// [`Registry::hist_observe`] on the global registry.
pub fn hist_observe(h: Hist, v: f64) {
    GLOBAL.hist_observe(h, v);
}

/// [`Registry::expert_tokens_add`] on the global registry.
pub fn expert_tokens_add(layer: usize, loads: &[u32]) {
    GLOBAL.expert_tokens_add(layer, loads);
}

/// [`Registry::expert_tokens_add_f32`] on the global registry.
pub fn expert_tokens_add_f32(layer: usize, loads: &[f32]) {
    GLOBAL.expert_tokens_add_f32(layer, loads);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_histogram_fits_the_preallocated_slots() {
        for h in Hist::ALL {
            assert!(
                h.bounds().len() + 1 <= HIST_SLOTS,
                "{}: {} bounds need {} slots, have {HIST_SLOTS}",
                h.name(),
                h.bounds().len(),
                h.bounds().len() + 1
            );
            assert!(
                h.bounds().windows(2).all(|w| w[0] < w[1]),
                "{}: bounds must strictly increase",
                h.name()
            );
        }
    }

    #[test]
    fn metric_names_are_unique() {
        let mut names: Vec<&str> = Counter::ALL
            .iter()
            .map(|c| c.name())
            .chain(Gauge::ALL.iter().map(|g| g.name()))
            .chain(Hist::ALL.iter().map(|h| h.name()))
            .collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate metric name");
    }

    #[test]
    fn enum_discriminants_are_dense_and_ordered() {
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i);
        }
        for (i, g) in Gauge::ALL.iter().enumerate() {
            assert_eq!(*g as usize, i);
        }
        for (i, h) in Hist::ALL.iter().enumerate() {
            assert_eq!(*h as usize, i);
        }
    }

    #[test]
    fn counters_saturate_at_u64_max_instead_of_panicking() {
        let cell = AtomicU64::new(u64::MAX - 3);
        saturating_add(&cell, 2);
        assert_eq!(cell.load(Ordering::Relaxed), u64::MAX - 1);
        saturating_add(&cell, 10);
        assert_eq!(cell.load(Ordering::Relaxed), u64::MAX);
        saturating_add(&cell, u64::MAX);
        assert_eq!(cell.load(Ordering::Relaxed), u64::MAX);
    }

    #[test]
    fn disabled_registry_drops_every_write() {
        let reg = Registry::new();
        reg.set_enabled(false);
        reg.counter_add(Counter::RouterBatches, 5);
        reg.gauge_set(Gauge::RouterLayers, 4.0);
        reg.hist_observe(Hist::SolverMaxVio, 0.1);
        reg.expert_tokens_add(0, &[1, 2, 3]);
        reg.set_enabled(true);
        let snap = crate::telemetry::scrape(&reg);
        assert_eq!(snap.counters[Counter::RouterBatches as usize], 0);
        assert_eq!(snap.gauges[Gauge::RouterLayers as usize], 0.0);
        assert_eq!(snap.hists[Hist::SolverMaxVio as usize].count(), 0);
    }

    #[test]
    fn out_of_bound_layers_and_experts_are_ignored() {
        let reg = Registry::new();
        reg.expert_tokens_add(MAX_LAYERS, &[7; 4]); // layer too deep
        let wide = vec![1u32; MAX_EXPERTS + 16]; // wider than tracked
        reg.expert_tokens_add(0, &wide);
        let snap = crate::telemetry::scrape(&reg);
        let total: u64 =
            snap.expert_tokens.iter().flatten().copied().sum();
        assert_eq!(total, MAX_EXPERTS as u64);
    }
}
