//! Incident flight recorder (ISSUE 8 tentpole, part 2).
//!
//! The recorder keeps a bounded history of telemetry scrapes and, when
//! a trigger fires — batch MaxVio over a configured ceiling, a
//! detector alert (shed storm and sync-divergence alerts map to their
//! own trigger codes), an explicit request, or a panic — dumps a
//! versioned **incident file**: a "BIPI" container in the same
//! length-prefixed little-endian conventions as the "BIPT" trace
//! format, holding the run identity, the causal event ring contents,
//! the scrape history, and the alert feed. An incident can name the
//! trace file recorded alongside it (`trace_path`), making the dump
//! replay-linkable: `bip-moe replay` the trace, `bip-moe incidents
//! inspect` the dump, and the batch ordinals line up.
//!
//! Read one back with [`Incident::load`]; `bip-moe incidents
//! inspect|export` wrap that for the terminal.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use crate::obs::detect::{Alert, AlertKind};
use crate::obs::event::{self, EventRecord};
use crate::telemetry::registry::{Counter, Gauge};
use crate::telemetry::{self, Snapshot};
use crate::trace::format::{ByteReader, ByteWriter};
use crate::util::json::Json;

pub const INCIDENT_MAGIC: [u8; 4] = *b"BIPI";
/// v1: header, events, scrapes, alerts — all length-prefixed blocks.
pub const INCIDENT_VERSION: u32 = 1;

/// Why an incident was dumped. Discriminants are written to disk;
/// never reuse a retired value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trigger {
    /// batch MaxVio crossed the recorder's ceiling
    MaxVio = 1,
    /// SLO attainment burn (reserved for the serving SLO watcher)
    SloBurn = 2,
    /// replica sync divergence jumped (detector sync alert)
    DualDivergence = 3,
    /// shed rate spiked (detector shed alert)
    ShedStorm = 4,
    /// any other detector alert (routing collapse included)
    Alert = 5,
    /// explicit dump request (CLI / tests)
    Manual = 6,
    /// process panicked with the hook installed
    Panic = 7,
}

const N_TRIGGERS: usize = 7;

impl Trigger {
    pub const ALL: [Trigger; N_TRIGGERS] = [
        Trigger::MaxVio,
        Trigger::SloBurn,
        Trigger::DualDivergence,
        Trigger::ShedStorm,
        Trigger::Alert,
        Trigger::Manual,
        Trigger::Panic,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Trigger::MaxVio => "maxvio",
            Trigger::SloBurn => "slo_burn",
            Trigger::DualDivergence => "dual_divergence",
            Trigger::ShedStorm => "shed_storm",
            Trigger::Alert => "alert",
            Trigger::Manual => "manual",
            Trigger::Panic => "panic",
        }
    }

    pub fn from_u8(v: u8) -> Option<Trigger> {
        Self::ALL.into_iter().find(|t| *t as u8 == v)
    }
}

/// Run identity and trigger context at dump time.
#[derive(Clone, Debug, PartialEq)]
pub struct IncidentHeader {
    /// on-disk format version the file was read with
    pub version: u32,
    pub crate_version: String,
    pub scenario: String,
    pub policy: String,
    /// detector tick at which the trigger fired
    pub tick: u64,
    pub trigger: Trigger,
    pub reason: String,
    /// raw value behind the trigger (e.g. the MaxVio sample)
    pub value: f64,
    pub threshold: f64,
    /// trace file recorded alongside this run ("" when none) — the
    /// replay link
    pub trace_path: String,
}

/// A full incident dump: identity + events + scrapes + alerts.
#[derive(Clone, Debug, PartialEq)]
pub struct Incident {
    pub header: IncidentHeader,
    /// causal event ring contents at dump time, oldest first
    pub events: Vec<EventRecord>,
    /// bounded scrape history: (tick, named series)
    pub scrapes: Vec<(u64, Vec<(String, f64)>)>,
    pub alerts: Vec<Alert>,
}

impl Incident {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.raw(&INCIDENT_MAGIC);
        w.u32(INCIDENT_VERSION);

        let h = &self.header;
        let start = w.begin_block();
        w.str(&h.crate_version);
        w.str(&h.scenario);
        w.str(&h.policy);
        w.u64(h.tick);
        w.u8(h.trigger as u8);
        w.str(&h.reason);
        w.f64(h.value);
        w.f64(h.threshold);
        w.str(&h.trace_path);
        w.end_block(start);

        w.u64(self.events.len() as u64);
        for e in &self.events {
            let start = w.begin_block();
            w.u64(e.seq);
            w.u8(e.kind as u8);
            w.u16(e.layer);
            w.u16(e.replica);
            w.u64(e.id);
            w.u64(e.payload);
            w.end_block(start);
        }

        w.u64(self.scrapes.len() as u64);
        for (tick, series) in &self.scrapes {
            let start = w.begin_block();
            w.u64(*tick);
            w.u32(series.len() as u32);
            for (name, value) in series {
                w.str(name);
                w.f64(*value);
            }
            w.end_block(start);
        }

        w.u64(self.alerts.len() as u64);
        for a in &self.alerts {
            let start = w.begin_block();
            w.u8(a.kind as u8);
            w.u64(a.tick);
            w.u16(a.layer);
            w.f64(a.score);
            w.f64(a.value);
            w.f64(a.threshold);
            w.str(&a.detail);
            w.end_block(start);
        }

        w.buf
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Incident> {
        let mut r = ByteReader::new(bytes);
        let magic = {
            let mut m = [0u8; 4];
            for slot in m.iter_mut() {
                *slot = r.u8()?;
            }
            m
        };
        if magic != INCIDENT_MAGIC {
            bail!("not a bip-moe incident (bad magic {magic:02x?})");
        }
        let version = r.u32()?;
        if version == 0 || version > INCIDENT_VERSION {
            bail!(
                "unsupported incident version {version} (this build \
                 reads versions 1..={INCIDENT_VERSION})"
            );
        }

        let mut hb = r.block()?;
        let crate_version = hb.str()?;
        let scenario = hb.str()?;
        let policy = hb.str()?;
        let tick = hb.u64()?;
        let trigger_code = hb.u8()?;
        let Some(trigger) = Trigger::from_u8(trigger_code) else {
            bail!("unknown incident trigger code {trigger_code}");
        };
        let header = IncidentHeader {
            version,
            crate_version,
            scenario,
            policy,
            tick,
            trigger,
            reason: hb.str()?,
            value: hb.f64()?,
            threshold: hb.f64()?,
            trace_path: hb.str()?,
        };

        let n = r.u64()? as usize;
        let mut events = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut b = r.block()?;
            let seq = b.u64()?;
            let kind_code = b.u8()?;
            let Some(kind) = event::EventKind::from_u8(kind_code) else {
                bail!("unknown incident event kind {kind_code}");
            };
            events.push(EventRecord {
                seq,
                kind,
                layer: b.u16()?,
                replica: b.u16()?,
                id: b.u64()?,
                payload: b.u64()?,
            });
        }

        let n = r.u64()? as usize;
        let mut scrapes = Vec::with_capacity(n.min(1 << 10));
        for _ in 0..n {
            let mut b = r.block()?;
            let tick = b.u64()?;
            let ns = b.u32()? as usize;
            let mut series = Vec::with_capacity(ns.min(1 << 10));
            for _ in 0..ns {
                let name = b.str()?;
                let value = b.f64()?;
                series.push((name, value));
            }
            scrapes.push((tick, series));
        }

        let n = r.u64()? as usize;
        let mut alerts = Vec::with_capacity(n.min(1 << 10));
        for _ in 0..n {
            let mut b = r.block()?;
            let kind_code = b.u8()?;
            let Some(kind) = AlertKind::from_u8(kind_code) else {
                bail!("unknown incident alert kind {kind_code}");
            };
            alerts.push(Alert {
                kind,
                tick: b.u64()?,
                layer: b.u16()?,
                score: b.f64()?,
                value: b.f64()?,
                threshold: b.f64()?,
                detail: b.str()?,
            });
        }

        Ok(Incident { header, events, scrapes, alerts })
    }

    /// Number of bytes written.
    pub fn save(&self, path: &Path) -> Result<usize> {
        let bytes = self.to_bytes();
        std::fs::write(path, &bytes).with_context(|| {
            format!("writing incident {}", path.display())
        })?;
        Ok(bytes.len())
    }

    pub fn load(path: &Path) -> Result<Incident> {
        let bytes = std::fs::read(path).with_context(|| {
            format!("reading incident {}", path.display())
        })?;
        Incident::from_bytes(&bytes).with_context(|| {
            format!("parsing incident {}", path.display())
        })
    }

    pub fn to_json(&self) -> Json {
        let h = &self.header;
        Json::obj(vec![
            ("format", Json::Str("bip-moe-incident".into())),
            ("version", Json::Num(h.version as f64)),
            (
                "header",
                Json::obj(vec![
                    (
                        "crate_version",
                        Json::Str(h.crate_version.clone()),
                    ),
                    ("scenario", Json::Str(h.scenario.clone())),
                    ("policy", Json::Str(h.policy.clone())),
                    ("tick", Json::Num(h.tick as f64)),
                    ("trigger", Json::Str(h.trigger.name().into())),
                    ("reason", Json::Str(h.reason.clone())),
                    ("value", Json::Num(h.value)),
                    ("threshold", Json::Num(h.threshold)),
                    ("trace_path", Json::Str(h.trace_path.clone())),
                ]),
            ),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("seq", Json::Num(e.seq as f64)),
                                ("kind", Json::Str(e.kind.name().into())),
                                ("layer", Json::Num(e.layer as f64)),
                                (
                                    "replica",
                                    Json::Num(e.replica as f64),
                                ),
                                ("id", Json::Num(e.id as f64)),
                                ("payload", Json::Num(e.payload as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "scrapes",
                Json::Arr(
                    self.scrapes
                        .iter()
                        .map(|(tick, series)| {
                            Json::obj(vec![
                                ("tick", Json::Num(*tick as f64)),
                                (
                                    "series",
                                    Json::Obj(
                                        series
                                            .iter()
                                            .map(|(k, v)| {
                                                (
                                                    k.clone(),
                                                    Json::Num(*v),
                                                )
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "alerts",
                Json::Arr(
                    self.alerts
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("kind", Json::Str(a.kind.name().into())),
                                ("tick", Json::Num(a.tick as f64)),
                                ("layer", Json::Num(a.layer as f64)),
                                ("score", Json::Num(a.score)),
                                ("value", Json::Num(a.value)),
                                ("threshold", Json::Num(a.threshold)),
                                ("detail", Json::Str(a.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Named counter/gauge series out of a [`Snapshot`] — same names the
/// Prometheus exposition uses, flat (name, value) pairs.
pub fn named_series(snap: &Snapshot) -> Vec<(String, f64)> {
    let mut out = Vec::with_capacity(
        Counter::ALL.len() + Gauge::ALL.len() + 1,
    );
    out.push(("elapsed_secs".to_string(), snap.elapsed_secs));
    for (c, v) in Counter::ALL.iter().zip(&snap.counters) {
        out.push((c.name().to_string(), *v as f64));
    }
    for (g, v) in Gauge::ALL.iter().zip(&snap.gauges) {
        out.push((g.name().to_string(), *v));
    }
    out
}

/// Flight-recorder knobs. `vio_threshold <= 0` disables the MaxVio
/// trigger; alerts always trigger.
#[derive(Clone, Debug)]
pub struct RecorderConfig {
    /// scrapes kept in the rolling history
    pub history: usize,
    /// batch-MaxVio ceiling; a gauge sample at or above it dumps
    pub vio_threshold: f64,
    /// most recent events included in a dump
    pub max_events: usize,
    /// dumps after which the recorder goes quiet (bounds disk use)
    pub max_incidents: usize,
    pub out_dir: PathBuf,
    pub scenario: String,
    pub policy: String,
    /// trace recorded alongside this run ("" when none)
    pub trace_path: String,
}

impl Default for RecorderConfig {
    fn default() -> Self {
        RecorderConfig {
            history: 32,
            vio_threshold: 0.0,
            max_events: event::EVENT_SLOTS,
            max_incidents: 4,
            out_dir: PathBuf::from("."),
            scenario: String::new(),
            policy: String::new(),
            trace_path: String::new(),
        }
    }
}

/// The live recorder: feed it one `(snapshot, alerts)` pair per
/// detector tick; it returns the path of any incident it dumped.
pub struct FlightRecorder {
    cfg: RecorderConfig,
    history: VecDeque<(u64, Vec<(String, f64)>)>,
    alerts: Vec<Alert>,
    dumped: Vec<PathBuf>,
}

const MAX_KEPT_ALERTS: usize = 64;

impl FlightRecorder {
    pub fn new(cfg: RecorderConfig) -> FlightRecorder {
        FlightRecorder {
            cfg,
            history: VecDeque::new(),
            alerts: Vec::new(),
            dumped: Vec::new(),
        }
    }

    pub fn dumped(&self) -> &[PathBuf] {
        &self.dumped
    }

    /// Record one tick; dump and return the incident path if a
    /// trigger fired (and the dump budget allows).
    pub fn observe(
        &mut self,
        tick: u64,
        snap: &Snapshot,
        alerts: &[Alert],
    ) -> Option<PathBuf> {
        self.history.push_back((tick, named_series(snap)));
        while self.history.len() > self.cfg.history.max(1) {
            self.history.pop_front();
        }
        for a in alerts {
            if self.alerts.len() < MAX_KEPT_ALERTS {
                self.alerts.push(a.clone());
            }
        }
        let vio = snap.gauge(Gauge::RouterLastBatchVio);
        if self.cfg.vio_threshold > 0.0 && vio >= self.cfg.vio_threshold
        {
            let reason = format!(
                "batch MaxVio {vio:.3} >= {:.3}",
                self.cfg.vio_threshold
            );
            return self.dump(
                tick,
                Trigger::MaxVio,
                reason,
                vio,
                self.cfg.vio_threshold,
            );
        }
        if let Some(a) = alerts.first() {
            let trigger = match a.kind {
                AlertKind::ShedStorm => Trigger::ShedStorm,
                AlertKind::SyncDivergence => Trigger::DualDivergence,
                _ => Trigger::Alert,
            };
            let reason =
                format!("{} alert: {}", a.kind.name(), a.detail);
            return self.dump(tick, trigger, reason, a.value, a.threshold);
        }
        None
    }

    /// Explicit dump, trigger [`Trigger::Manual`].
    pub fn dump_manual(&mut self, tick: u64) -> Option<PathBuf> {
        self.dump(
            tick,
            Trigger::Manual,
            "manual dump".to_string(),
            0.0,
            0.0,
        )
    }

    fn dump(
        &mut self,
        tick: u64,
        trigger: Trigger,
        reason: String,
        value: f64,
        threshold: f64,
    ) -> Option<PathBuf> {
        if self.dumped.len() >= self.cfg.max_incidents {
            return None;
        }
        let inc = Incident {
            header: IncidentHeader {
                version: INCIDENT_VERSION,
                crate_version: env!("CARGO_PKG_VERSION").to_string(),
                scenario: self.cfg.scenario.clone(),
                policy: self.cfg.policy.clone(),
                tick,
                trigger,
                reason,
                value,
                threshold,
                trace_path: self.cfg.trace_path.clone(),
            },
            events: event::recent_events(self.cfg.max_events),
            scrapes: self.history.iter().cloned().collect(),
            alerts: self.alerts.clone(),
        };
        let name = format!(
            "incident-{}-{}-t{tick}.bipi",
            safe_name(&self.cfg.scenario),
            safe_name(&self.cfg.policy)
        );
        let path = self.cfg.out_dir.join(name);
        if std::fs::create_dir_all(&self.cfg.out_dir).is_err() {
            return None;
        }
        if inc.save(&path).is_err() {
            return None;
        }
        telemetry::counter_add(Counter::ObsIncidents, 1);
        self.dumped.push(path.clone());
        Some(path)
    }
}

fn safe_name(s: &str) -> String {
    let cleaned: String = s
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    if cleaned.is_empty() {
        "unknown".to_string()
    } else {
        cleaned
    }
}

static PANIC_DIR: OnceLock<PathBuf> = OnceLock::new();

/// Install a panic hook that dumps a best-effort incident (ring
/// contents + a final scrape of the global registry) before the
/// default hook runs. Idempotent on the directory: the first caller
/// wins.
pub fn install_panic_hook(out_dir: &Path, scenario: &str, policy: &str) {
    let _ = PANIC_DIR.set(out_dir.to_path_buf());
    let scenario = safe_name(scenario);
    let policy = safe_name(policy);
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        if let Some(dir) = PANIC_DIR.get() {
            let snap = telemetry::scrape(telemetry::global());
            let inc = Incident {
                header: IncidentHeader {
                    version: INCIDENT_VERSION,
                    crate_version: env!("CARGO_PKG_VERSION")
                        .to_string(),
                    scenario: scenario.clone(),
                    policy: policy.clone(),
                    tick: 0,
                    trigger: Trigger::Panic,
                    reason: format!("{info}"),
                    value: 0.0,
                    threshold: 0.0,
                    trace_path: String::new(),
                },
                events: event::recent_events(event::EVENT_SLOTS),
                scrapes: vec![(0, named_series(&snap))],
                alerts: Vec::new(),
            };
            let _ = std::fs::create_dir_all(dir);
            let _ = inc.save(&dir.join(format!(
                "incident-panic-{scenario}-{policy}.bipi"
            )));
        }
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::EventKind;

    fn sample_incident() -> Incident {
        Incident {
            header: IncidentHeader {
                version: INCIDENT_VERSION,
                crate_version: "0.1.0".into(),
                scenario: "degraded".into(),
                policy: "bip".into(),
                tick: 7,
                trigger: Trigger::Alert,
                reason: "routing_collapse alert".into(),
                value: 0.31,
                threshold: 0.2,
                trace_path: "run.bipt".into(),
            },
            events: vec![
                EventRecord {
                    seq: 1,
                    kind: EventKind::Admit,
                    layer: 0,
                    replica: 0,
                    id: 11,
                    payload: 0,
                },
                EventRecord {
                    seq: 2,
                    kind: EventKind::BatchDone,
                    layer: 3,
                    replica: 1,
                    id: 4,
                    payload: f64::to_bits(0.5),
                },
            ],
            scrapes: vec![(
                6,
                vec![
                    ("router_batches_total".into(), 12.0),
                    ("router_last_batch_maxvio".into(), 0.5),
                ],
            )],
            alerts: vec![Alert {
                kind: AlertKind::RoutingCollapse,
                tick: 7,
                layer: 3,
                score: 0.31,
                value: 0.5,
                threshold: 0.2,
                detail: "layer 3 concentrated".into(),
            }],
        }
    }

    #[test]
    fn incident_round_trips_bit_exactly() {
        let inc = sample_incident();
        let back = Incident::from_bytes(&inc.to_bytes()).unwrap();
        assert_eq!(back.header, inc.header);
        assert_eq!(back.events, inc.events);
        assert_eq!(back.scrapes, inc.scrapes);
        assert_eq!(back.alerts.len(), inc.alerts.len());
        assert_eq!(back.alerts[0].detail, inc.alerts[0].detail);
        let json = format!("{}", back.to_json());
        assert!(json.contains("bip-moe-incident"), "{json}");
        assert!(json.contains("routing_collapse"), "{json}");
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        assert!(Incident::from_bytes(b"nope").is_err());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&INCIDENT_MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        let err = Incident::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }

    #[test]
    fn triggers_pack_into_a_byte_and_back() {
        for t in Trigger::ALL {
            assert_eq!(Trigger::from_u8(t as u8), Some(t));
            assert!(!t.name().is_empty());
        }
        assert_eq!(Trigger::from_u8(0), None);
    }

    #[test]
    fn recorder_dumps_on_maxvio_and_respects_budget() {
        let dir = std::env::temp_dir().join(format!(
            "bip_moe_obs_rec_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = crate::telemetry::registry::Registry::new();
        reg.set_enabled(true);
        let mut rec = FlightRecorder::new(RecorderConfig {
            vio_threshold: 0.5,
            max_incidents: 1,
            out_dir: dir.clone(),
            scenario: "steady".into(),
            policy: "bip".into(),
            ..RecorderConfig::default()
        });
        reg.gauge_set(Gauge::RouterLastBatchVio, 0.1);
        let calm = telemetry::scrape(&reg);
        assert!(rec.observe(1, &calm, &[]).is_none());
        reg.gauge_set(Gauge::RouterLastBatchVio, 0.9);
        let hot = telemetry::scrape(&reg);
        let path = rec.observe(2, &hot, &[]).expect("dump fired");
        let inc = Incident::load(&path).unwrap();
        assert_eq!(inc.header.trigger, Trigger::MaxVio);
        assert_eq!(inc.header.tick, 2);
        assert_eq!(inc.scrapes.len(), 2, "history retained");
        // budget: a second trigger stays quiet
        assert!(rec.observe(3, &hot, &[]).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn shed_alert(tick: u64) -> Alert {
        Alert {
            kind: AlertKind::ShedStorm,
            tick,
            layer: 0,
            score: 0.8,
            value: 0.4,
            threshold: 0.1,
            detail: "shed rate spiked".into(),
        }
    }

    fn bipi_files(dir: &Path) -> Vec<PathBuf> {
        let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.extension().and_then(|e| e.to_str())
                            == Some("bipi")
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.sort();
        out
    }

    #[test]
    fn max_incidents_cap_refuses_at_the_boundary() {
        let dir = std::env::temp_dir().join(format!(
            "bip_moe_obs_rec_cap_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = crate::telemetry::registry::Registry::new();
        reg.set_enabled(true);
        let mut rec = FlightRecorder::new(RecorderConfig {
            vio_threshold: 0.5,
            max_incidents: 2,
            out_dir: dir.clone(),
            scenario: "burst".into(),
            policy: "bip".into(),
            ..RecorderConfig::default()
        });
        reg.gauge_set(Gauge::RouterLastBatchVio, 0.9);
        let hot = telemetry::scrape(&reg);
        // three triggering ticks against a budget of two: the first
        // two dump, the third is refused (no eviction, no overwrite)
        let first = rec.observe(1, &hot, &[]).expect("first dump");
        let second = rec.observe(2, &hot, &[]).expect("second dump");
        assert_ne!(first, second, "tick-stamped names stay distinct");
        assert!(rec.observe(3, &hot, &[]).is_none(), "budget refused");
        assert_eq!(rec.dumped().len(), 2);
        assert_eq!(bipi_files(&dir).len(), 2, "exactly two files");
        // the refused tick must not have clobbered either survivor
        for path in [&first, &second] {
            let inc = Incident::load(path).unwrap();
            assert_eq!(inc.header.trigger, Trigger::MaxVio);
            assert!(inc.header.tick < 3, "third tick never hit disk");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn maxvio_and_alert_on_one_tick_dump_exactly_once() {
        let dir = std::env::temp_dir().join(format!(
            "bip_moe_obs_rec_once_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = crate::telemetry::registry::Registry::new();
        reg.set_enabled(true);
        let mut rec = FlightRecorder::new(RecorderConfig {
            vio_threshold: 0.5,
            max_incidents: 4,
            out_dir: dir.clone(),
            scenario: "degraded".into(),
            policy: "bip".into(),
            ..RecorderConfig::default()
        });
        reg.gauge_set(Gauge::RouterLastBatchVio, 0.9);
        let hot = telemetry::scrape(&reg);
        // both triggers are live on the same tick; the alert firing
        // while the MaxVio dump is in progress must not double-write
        let path = rec
            .observe(5, &hot, &[shed_alert(5)])
            .expect("one dump fired");
        assert_eq!(rec.dumped().len(), 1, "one dump, not two");
        assert_eq!(bipi_files(&dir).len(), 1, "one file on disk");
        let inc = Incident::load(&path).unwrap();
        assert_eq!(
            inc.header.trigger,
            Trigger::MaxVio,
            "MaxVio outranks the alert trigger"
        );
        // the alert still rides along inside the single incident, and
        // the file round-trips bit-exactly through the BIPI codec
        assert_eq!(inc.alerts.len(), 1);
        assert_eq!(inc.alerts[0].detail, "shed rate spiked");
        let back = Incident::from_bytes(&inc.to_bytes()).unwrap();
        assert_eq!(back.header, inc.header);
        assert_eq!(back.events, inc.events);
        assert_eq!(back.scrapes, inc.scrapes);
        assert_eq!(back.alerts.len(), inc.alerts.len());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
