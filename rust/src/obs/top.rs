//! The `bip-moe top` dashboard renderer (ISSUE 8 tentpole, part 4).
//!
//! Pure string rendering over telemetry snapshots: the CLI loop
//! scrapes, feeds [`TopState::update`], and prints
//! [`TopState::render`]. Keeping the renderer side-effect free makes
//! the dashboard testable (the CI smoke asserts on the rendered text)
//! and keeps every terminal concern — ANSI clearing, unicode vs
//! `--plain` glyphs — in one place.
//!
//! Layout, top to bottom: run header (tick, elapsed, batch/token
//! rates), per-layer expert-load heat rows (one glyph per expert,
//! scaled by that layer's share spread this tick), the batch-MaxVio
//! sparkline with the collapse score, the live series table, and the
//! alert feed.

use std::collections::VecDeque;

use crate::obs::detect::Alert;
use crate::telemetry::registry::{Counter, Gauge};
use crate::telemetry::Snapshot;

/// Heat glyphs, cold to hot (`--plain` ASCII ramp).
const HEAT_PLAIN: &[char] =
    &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
/// Sparkline glyphs, low to high.
const SPARK: &[char] = &['\u{2581}', '\u{2582}', '\u{2583}', '\u{2584}',
    '\u{2585}', '\u{2586}', '\u{2587}', '\u{2588}'];
const SPARK_PLAIN: &[char] = &['.', ':', '-', '=', '+', '*', '#', '@'];

/// How many MaxVio samples the sparkline keeps.
pub const SPARK_WIDTH: usize = 48;
/// How many alerts the feed shows.
pub const FEED_LEN: usize = 6;

fn ramp(glyphs: &[char], frac: f64) -> char {
    let f = frac.clamp(0.0, 1.0);
    let i = (f * (glyphs.len() - 1) as f64).round() as usize;
    glyphs.get(i).copied().unwrap_or(' ')
}

/// Rolling dashboard state between scrapes.
pub struct TopState {
    tick: u64,
    vio_history: VecDeque<f64>,
    feed: VecDeque<Alert>,
    prev: Option<Snapshot>,
}

impl Default for TopState {
    fn default() -> Self {
        Self::new()
    }
}

impl TopState {
    pub fn new() -> TopState {
        TopState {
            tick: 0,
            vio_history: VecDeque::new(),
            feed: VecDeque::new(),
            prev: None,
        }
    }

    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// Fold in one scrape and the alerts its detector tick raised.
    pub fn update(&mut self, snap: &Snapshot, alerts: &[Alert]) {
        self.tick += 1;
        self.vio_history
            .push_back(snap.gauge(Gauge::RouterLastBatchVio));
        while self.vio_history.len() > SPARK_WIDTH {
            self.vio_history.pop_front();
        }
        for a in alerts {
            self.feed.push_back(a.clone());
            while self.feed.len() > FEED_LEN {
                self.feed.pop_front();
            }
        }
        self.prev = Some(snap.clone());
    }

    /// Render the dashboard against `snap` (the scrape most recently
    /// passed to [`TopState::update`]). `plain` swaps ANSI clearing
    /// and unicode glyphs for pipe-safe ASCII.
    pub fn render(&self, snap: &Snapshot, plain: bool) -> String {
        let mut out = String::new();
        if !plain {
            out.push_str("\x1b[2J\x1b[H");
        }
        let batches = snap.counter(Counter::RouterBatches);
        let tokens = snap.counter(Counter::RouterTokens);
        out.push_str(&format!(
            "bip-moe top | tick {} | {:.1}s | {} batches | {} tokens \
             | queue {:.0} | replicas {:.0}\n",
            self.tick,
            snap.elapsed_secs,
            batches,
            tokens,
            snap.gauge(Gauge::ServeQueueDepth),
            snap.gauge(Gauge::AutoscaleReplicas).max(1.0),
        ));

        self.render_heat(snap, &mut out);
        self.render_spark(snap, plain, &mut out);
        self.render_series(snap, &mut out);
        self.render_feed(&mut out);
        out
    }

    /// Per-layer expert-load heat rows over this tick's token deltas
    /// (cumulative grid minus the previous scrape's). The ramp is
    /// ASCII in both modes — it reads fine in pipes and terminals.
    fn render_heat(&self, snap: &Snapshot, out: &mut String) {
        let glyphs = HEAT_PLAIN;
        let empty: Vec<Vec<u64>> = Vec::new();
        let prev_grid = self
            .prev
            .as_ref()
            .map(|p| &p.expert_tokens)
            .unwrap_or(&empty);
        if snap.expert_tokens.is_empty() {
            out.push_str("experts: (no routed tokens yet)\n");
            return;
        }
        out.push_str("expert load by layer (this tick):\n");
        for (l, row) in snap.expert_tokens.iter().enumerate() {
            let prev_row = prev_grid.get(l);
            let mut deltas: Vec<u64> = Vec::with_capacity(row.len());
            for (e, &cum) in row.iter().enumerate() {
                let before = prev_row
                    .and_then(|p| p.get(e))
                    .copied()
                    .unwrap_or(0);
                deltas.push(cum.saturating_sub(before));
            }
            let total: u64 = deltas.iter().sum();
            let peak = deltas.iter().copied().max().unwrap_or(0);
            out.push_str(&format!("  L{l:<2} "));
            for &d in &deltas {
                let frac = if peak == 0 {
                    0.0
                } else {
                    d as f64 / peak as f64
                };
                out.push(ramp(glyphs, frac));
            }
            let (hot_e, hot_share) = deltas
                .iter()
                .enumerate()
                .max_by_key(|&(_, &d)| d)
                .map(|(e, &d)| {
                    let share = if total == 0 {
                        0.0
                    } else {
                        d as f64 / total as f64
                    };
                    (e, share)
                })
                .unwrap_or((0, 0.0));
            out.push_str(&format!(
                "  hot e{hot_e} {:.0}%\n",
                hot_share * 100.0
            ));
        }
    }

    fn render_spark(
        &self,
        snap: &Snapshot,
        plain: bool,
        out: &mut String,
    ) {
        let glyphs = if plain { SPARK_PLAIN } else { SPARK };
        let peak = self
            .vio_history
            .iter()
            .copied()
            .fold(0.0f64, f64::max)
            .max(1e-9);
        out.push_str(&format!(
            "maxvio {:>7.3} |",
            snap.gauge(Gauge::RouterLastBatchVio)
        ));
        for &v in &self.vio_history {
            out.push(ramp(glyphs, v / peak));
        }
        out.push_str(&format!(
            "| peak {peak:.3} | collapse score {:.3}\n",
            snap.gauge(Gauge::ObsCollapseScore)
        ));
    }

    fn render_series(&self, snap: &Snapshot, out: &mut String) {
        let d = |c: Counter| -> u64 {
            let now = snap.counter(c);
            let before =
                self.prev.as_ref().map(|p| p.counter(c)).unwrap_or(0);
            now.saturating_sub(before)
        };
        out.push_str(&format!(
            "solver: {:.0} iters/solve | sheds +{} | overflow +{} | \
             sync div {:.3} | events {} | alerts {} | incidents {}\n",
            snap.gauge(Gauge::SolverLastIters),
            d(Counter::ServeShed),
            d(Counter::RouterOverflow),
            snap.gauge(Gauge::ReplicaLastSyncDivergence),
            snap.counter(Counter::ObsEvents),
            snap.counter(Counter::ObsAlerts),
            snap.counter(Counter::ObsIncidents),
        ));
    }

    fn render_feed(&self, out: &mut String) {
        if self.feed.is_empty() {
            out.push_str("alerts: none\n");
            return;
        }
        out.push_str("alerts:\n");
        for a in self.feed.iter().rev() {
            out.push_str(&format!(
                "  [t{:>4}] {:<16} {}\n",
                a.tick,
                a.kind.name(),
                a.detail
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::detect::AlertKind;
    use crate::telemetry::registry::Registry;
    use crate::telemetry::scrape;

    #[test]
    fn render_covers_every_section_and_is_plain_safe() {
        let reg = Registry::new();
        reg.set_enabled(true);
        reg.counter_add(Counter::RouterBatches, 3);
        reg.counter_add(Counter::RouterTokens, 96);
        reg.expert_tokens_add(0, &[10, 2, 2, 2]);
        reg.gauge_set(Gauge::RouterLastBatchVio, 0.4);
        let snap = scrape(&reg);
        let mut st = TopState::new();
        st.update(
            &snap,
            &[Alert {
                kind: AlertKind::RoutingCollapse,
                tick: 1,
                layer: 0,
                score: 0.5,
                value: 0.4,
                threshold: 0.2,
                detail: "layer 0 hot".into(),
            }],
        );
        let text = st.render(&snap, true);
        assert!(text.contains("bip-moe top"), "{text}");
        assert!(text.contains("expert load by layer"), "{text}");
        assert!(text.contains("maxvio"), "{text}");
        assert!(text.contains("routing_collapse"), "{text}");
        assert!(text.contains("hot e0"), "{text}");
        assert!(
            !text.contains('\u{1b}'),
            "plain output must not emit ANSI"
        );
    }

    #[test]
    fn empty_state_renders_placeholders() {
        let reg = Registry::new();
        reg.set_enabled(true);
        let snap = scrape(&reg);
        let st = TopState::new();
        let text = st.render(&snap, true);
        assert!(text.contains("no routed tokens"), "{text}");
        assert!(text.contains("alerts: none"), "{text}");
    }

    #[test]
    fn sparkline_is_bounded() {
        let reg = Registry::new();
        reg.set_enabled(true);
        let mut st = TopState::new();
        for i in 0..(SPARK_WIDTH + 20) {
            reg.gauge_set(Gauge::RouterLastBatchVio, i as f64 * 0.01);
            st.update(&scrape(&reg), &[]);
        }
        assert_eq!(st.vio_history.len(), SPARK_WIDTH);
        assert_eq!(st.tick(), (SPARK_WIDTH + 20) as u64);
    }
}
