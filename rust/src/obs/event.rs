//! Zero-alloc causal event ring (ISSUE 8 tentpole, part 1).
//!
//! Every step of a request's life — admission, shed, per-batch
//! routing, per-layer dispatch, solver exit, replica sync — drops one
//! fixed-size record into a sharded global ring: four `AtomicU64`
//! words (`stamp`, `meta`, `id`, `payload`) written with a seqlock
//! stamp so a concurrent scrape can *lose* records under pressure but
//! can never observe a torn one. Nothing on the write path allocates
//! or locks: the shard index reuses the telemetry registry's
//! thread-affine hash, causal context (current batch / layer /
//! replica) lives in `thread_local!` `Cell`s, and the sequence number
//! is one relaxed `fetch_add`. This is what lets a MaxVio sample be
//! walked back to the batch, replica, and solver exit reason that
//! produced it (see DESIGN.md `obs/`).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::telemetry::registry::{self, Counter, Gauge};

/// Shards of the event ring (matches the registry's shard count so
/// [`registry::shard_index`] keeps writers thread-affine).
pub const EVENT_SHARDS: usize = 16;
/// Slots per shard; total capacity is `EVENT_SHARDS * SHARD_SLOTS`.
pub const SHARD_SLOTS: usize = 256;
/// Total ring capacity in records.
pub const EVENT_SLOTS: usize = EVENT_SHARDS * SHARD_SLOTS;

/// The event vocabulary. Discriminants are packed into the top byte
/// of the `meta` word (and into incident files), so keep them within
/// `u8` and never reuse a retired value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// request admitted by the scheduler (`id` = request id)
    Admit = 1,
    /// request rejected at admission (`id` = request id)
    Reject = 2,
    /// request shed by the micro-batcher (`id` = request id)
    Shed = 3,
    /// batch entered routing (`id` = batch ordinal, `payload` packs
    /// first request id and token count — see [`batch_start_payload`])
    BatchStart = 4,
    /// one MoE layer routed within the current batch (`meta` carries
    /// the layer, `id` = batch ordinal)
    LayerRoute = 5,
    /// per-batch solve returned (`payload` packs mode/capped/iters —
    /// see [`solver_exit_payload`])
    SolverExit = 6,
    /// Algorithm-1 adaptive loop exited (`payload` packs the exit
    /// reason and iteration count — see [`dual_exit_payload`])
    DualExit = 7,
    /// batch finished routing (`payload` = `f64::to_bits(batch_vio)`)
    BatchDone = 8,
    /// one replica's dispatch job finished (`payload` = service us)
    Dispatch = 9,
    /// replica merge-sync (`id` = sync ordinal, `payload` =
    /// `f64::to_bits(divergence_before)`)
    Sync = 10,
    /// anomaly detector raised an alert (`payload` = alert kind)
    Alert = 11,
}

const N_EVENT_KINDS: usize = 11;

impl EventKind {
    pub const ALL: [EventKind; N_EVENT_KINDS] = [
        EventKind::Admit,
        EventKind::Reject,
        EventKind::Shed,
        EventKind::BatchStart,
        EventKind::LayerRoute,
        EventKind::SolverExit,
        EventKind::DualExit,
        EventKind::BatchDone,
        EventKind::Dispatch,
        EventKind::Sync,
        EventKind::Alert,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::Shed => "shed",
            EventKind::BatchStart => "batch_start",
            EventKind::LayerRoute => "layer_route",
            EventKind::SolverExit => "solver_exit",
            EventKind::DualExit => "dual_exit",
            EventKind::BatchDone => "batch_done",
            EventKind::Dispatch => "dispatch",
            EventKind::Sync => "sync",
            EventKind::Alert => "alert",
        }
    }

    pub fn from_u8(v: u8) -> Option<EventKind> {
        Self::ALL.into_iter().find(|k| *k as u8 == v)
    }
}

// Ring storage. Four parallel word arrays instead of a struct array
// so each field is one naturally aligned atomic. `stamp` is the
// seqlock word: 0 = unwritten or mid-write, otherwise the global
// sequence number of the record occupying the slot.
const ZERO: AtomicU64 = AtomicU64::new(0);
static STAMP: [AtomicU64; EVENT_SLOTS] = [ZERO; EVENT_SLOTS];
static META: [AtomicU64; EVENT_SLOTS] = [ZERO; EVENT_SLOTS];
static ID: [AtomicU64; EVENT_SLOTS] = [ZERO; EVENT_SLOTS];
static PAYLOAD: [AtomicU64; EVENT_SLOTS] = [ZERO; EVENT_SLOTS];
static HEADS: [AtomicU64; EVENT_SHARDS] = [ZERO; EVENT_SHARDS];
static EVENT_SEQ: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static CTX_BATCH: Cell<u64> = const { Cell::new(0) };
    static CTX_LAYER: Cell<u16> = const { Cell::new(0) };
    static CTX_REPLICA: Cell<u16> = const { Cell::new(0) };
}

const META_KIND_SHIFT: u32 = 56;
const META_LAYER_SHIFT: u32 = 40;
const META_REPLICA_SHIFT: u32 = 24;

// HOT: per-event encode — TLS reads plus relaxed/seqlock atomic
// stores into preallocated slots; no locks, no allocation.
pub fn record_event(kind: EventKind, id: u64, payload: u64) {
    if !registry::enabled() {
        return;
    }
    let seq = EVENT_SEQ.fetch_add(1, Ordering::Relaxed) + 1;
    let shard = registry::shard_index() % EVENT_SHARDS;
    let slot = (HEADS[shard].fetch_add(1, Ordering::Relaxed) as usize)
        % SHARD_SLOTS;
    let at = shard * SHARD_SLOTS + slot;
    let layer = CTX_LAYER.with(|c| c.get());
    let replica = CTX_REPLICA.with(|c| c.get());
    let meta = ((kind as u64) << META_KIND_SHIFT)
        | ((layer as u64) << META_LAYER_SHIFT)
        | ((replica as u64) << META_REPLICA_SHIFT);
    // seqlock write: invalidate, fill, publish. A reader that races
    // us sees stamp 0 (skip) or a stamp change (discard) — never a
    // mix of old and new fields.
    STAMP[at].store(0, Ordering::Release);
    META[at].store(meta, Ordering::Relaxed);
    ID[at].store(id, Ordering::Relaxed);
    PAYLOAD[at].store(payload, Ordering::Relaxed);
    STAMP[at].store(seq, Ordering::Release);
    registry::counter_add(Counter::ObsEvents, 1);
    registry::gauge_set(
        Gauge::ObsEventRingOccupancy,
        seq.min(EVENT_SLOTS as u64) as f64,
    );
}

// HOT: per-event encode of a batch-scoped event — the current batch
// ordinal (TLS) becomes the causal id; no locks, no allocation.
pub fn record_ctx_event(kind: EventKind, payload: u64) {
    record_event(kind, CTX_BATCH.with(|c| c.get()), payload);
}

// HOT: per-batch causal-context open — two TLS stores plus one
// BatchStart record; no locks, no allocation.
pub fn begin_batch(batch_id: u64, first_req: u64, n_tokens: usize) {
    if !registry::enabled() {
        return;
    }
    CTX_BATCH.with(|c| c.set(batch_id));
    CTX_LAYER.with(|c| c.set(0));
    record_event(
        EventKind::BatchStart,
        batch_id,
        batch_start_payload(first_req, n_tokens),
    );
}

// HOT: per-layer causal-context update — one TLS store plus one
// LayerRoute record; no locks, no allocation.
pub fn set_layer_ctx(layer: usize) {
    if !registry::enabled() {
        return;
    }
    let l = layer.min(u16::MAX as usize) as u16;
    CTX_LAYER.with(|c| c.set(l));
    record_ctx_event(EventKind::LayerRoute, l as u64);
}

// HOT: per-dispatch causal-context update — one TLS store; no locks,
// no allocation. Sticky for the worker thread until set again.
pub fn set_replica_ctx(replica: usize) {
    CTX_REPLICA
        .with(|c| c.set(replica.min(u16::MAX as usize) as u16));
}

/// The batch ordinal currently open on this thread (0 before any
/// [`begin_batch`]).
pub fn batch_ctx() -> u64 {
    CTX_BATCH.with(|c| c.get())
}

/// Pack a BatchStart payload: first admitted request id in the high
/// bits, token count (clamped to u16) in the low 16.
pub fn batch_start_payload(first_req: u64, n_tokens: usize) -> u64 {
    (first_req << 16) | n_tokens.min(u16::MAX as usize) as u64
}

/// Unpack [`batch_start_payload`] → `(first_req, n_tokens)`.
pub fn batch_start_fields(payload: u64) -> (u64, usize) {
    (payload >> 16, (payload & u16::MAX as u64) as usize)
}

/// Pack a SolverExit payload: solve mode (0 fixed-serial, 1
/// fixed-parallel, 2 adaptive-serial, 3 adaptive-parallel), whether
/// the adaptive loop hit its iteration cap, and the iteration count.
pub fn solver_exit_payload(mode: u8, capped: bool, iters: usize) -> u64 {
    ((mode as u64) << 56)
        | ((capped as u64) << 48)
        | (iters as u64 & ((1u64 << 48) - 1))
}

/// Unpack [`solver_exit_payload`] → `(mode, capped, iters)`.
pub fn solver_exit_fields(payload: u64) -> (u8, bool, usize) {
    (
        (payload >> 56) as u8,
        (payload >> 48) & 1 == 1,
        (payload & ((1u64 << 48) - 1)) as usize,
    )
}

/// Adaptive-loop exit reasons packed into [`dual_exit_payload`].
pub const DUAL_EXIT_CAPPED: u8 = 0;
pub const DUAL_EXIT_FIXPOINT: u8 = 1;
pub const DUAL_EXIT_CONVERGED: u8 = 2;

/// Pack a DualExit payload: exit reason in the top byte, iteration
/// count below.
pub fn dual_exit_payload(reason: u8, iters: usize) -> u64 {
    ((reason as u64) << 56) | (iters as u64 & ((1u64 << 56) - 1))
}

/// Unpack [`dual_exit_payload`] → `(reason, iters)`.
pub fn dual_exit_fields(payload: u64) -> (u8, usize) {
    ((payload >> 56) as u8, (payload & ((1u64 << 56) - 1)) as usize)
}

/// Human name for a DualExit reason code.
pub fn dual_exit_reason_name(reason: u8) -> &'static str {
    match reason {
        DUAL_EXIT_FIXPOINT => "fixpoint",
        DUAL_EXIT_CONVERGED => "converged",
        _ => "capped",
    }
}

/// One decoded event as read back out of the ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EventRecord {
    /// global sequence number (1-based, monotone across shards)
    pub seq: u64,
    pub kind: EventKind,
    /// MoE layer context at record time (0 outside routing)
    pub layer: u16,
    /// replica context at record time (0 in single-replica serving)
    pub replica: u16,
    /// causal id: request id for admission events, batch ordinal for
    /// routing/solver events, sync ordinal for Sync
    pub id: u64,
    pub payload: u64,
}

fn read_slot(at: usize) -> Option<EventRecord> {
    let s1 = STAMP[at].load(Ordering::Acquire);
    if s1 == 0 {
        return None;
    }
    let meta = META[at].load(Ordering::Relaxed);
    let id = ID[at].load(Ordering::Relaxed);
    let payload = PAYLOAD[at].load(Ordering::Relaxed);
    let s2 = STAMP[at].load(Ordering::Acquire);
    if s1 != s2 {
        return None; // torn by a concurrent writer — drop, don't lie
    }
    let kind = EventKind::from_u8((meta >> META_KIND_SHIFT) as u8)?;
    Some(EventRecord {
        seq: s1,
        kind,
        layer: ((meta >> META_LAYER_SHIFT) & 0xffff) as u16,
        replica: ((meta >> META_REPLICA_SHIFT) & 0xffff) as u16,
        id,
        payload,
    })
}

/// The most recent `max` events across every shard, oldest first (so
/// a causal chain reads top to bottom). Allocates — scrape-side only
/// — and is loss-bounded under concurrent writes: records may be
/// missing, never torn.
pub fn recent_events(max: usize) -> Vec<EventRecord> {
    let mut out = Vec::with_capacity(EVENT_SLOTS.min(max));
    for at in 0..EVENT_SLOTS {
        if let Some(r) = read_slot(at) {
            out.push(r);
        }
    }
    out.sort_by_key(|r| r.seq);
    if out.len() > max {
        out.drain(..out.len() - max);
    }
    out
}

/// Total events ever recorded (monotone; survives ring wrap).
pub fn events_recorded() -> u64 {
    EVENT_SEQ.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_pack_into_a_byte_and_back() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_u8(k as u8), Some(k));
            assert!(!k.name().is_empty());
        }
        assert_eq!(EventKind::from_u8(0), None);
        assert_eq!(EventKind::from_u8(200), None);
    }

    #[test]
    fn payload_packers_round_trip() {
        assert_eq!(batch_start_fields(batch_start_payload(7, 33)), (7, 33));
        assert_eq!(
            solver_exit_fields(solver_exit_payload(3, true, 41)),
            (3, true, 41)
        );
        assert_eq!(
            dual_exit_fields(dual_exit_payload(DUAL_EXIT_CONVERGED, 9)),
            (DUAL_EXIT_CONVERGED, 9)
        );
        assert_eq!(dual_exit_reason_name(DUAL_EXIT_FIXPOINT), "fixpoint");
    }

    #[test]
    fn recorded_events_carry_causal_context() {
        crate::telemetry::set_enabled(true);
        set_replica_ctx(3);
        begin_batch(42, 9000, 17);
        set_layer_ctx(5);
        record_ctx_event(EventKind::BatchDone, f64::to_bits(0.25));
        set_replica_ctx(0);
        let recent = recent_events(EVENT_SLOTS);
        let done = recent
            .iter()
            .rev()
            .find(|r| {
                r.kind == EventKind::BatchDone && r.id == 42 && r.replica == 3
            })
            .expect("our BatchDone is in the ring");
        assert_eq!(done.layer, 5);
        assert_eq!(f64::from_bits(done.payload), 0.25);
        let start = recent
            .iter()
            .find(|r| r.kind == EventKind::BatchStart && r.id == 42)
            .expect("our BatchStart is in the ring");
        assert!(start.seq < done.seq, "causal order preserved");
        assert_eq!(batch_start_fields(start.payload), (9000, 17));
    }

    #[test]
    fn ring_read_is_bounded_and_ordered() {
        crate::telemetry::set_enabled(true);
        for i in 0..10 {
            record_event(EventKind::Admit, i, 0);
        }
        let few = recent_events(4);
        assert!(few.len() <= 4);
        for w in few.windows(2) {
            assert!(w[0].seq < w[1].seq, "oldest first");
        }
        assert!(recent_events(usize::MAX).len() <= EVENT_SLOTS);
        assert!(events_recorded() >= 10);
    }
}
