//! Online anomaly detection over telemetry snapshots (ISSUE 8
//! tentpole, part 3).
//!
//! The detector consumes successive registry [`Snapshot`]s (one per
//! observation "tick") and scores a handful of series the way an SRE
//! would eyeball them:
//!
//! * **per-layer expert token shares** — deltas of the cumulative
//!   `[layer][expert]` grid, with an [`Ewma`] forecaster (the same
//!   baseline predictor `forecast/` ships, per "Prediction Is All MoE
//!   Needs") as the expected-share baseline. The **routing-collapse
//!   early warning** fires when the hottest `hot_k` experts of a
//!   layer hold more than `share_threshold` of that layer's tokens
//!   for `sustain_ticks` consecutive ticks *and* the batch-MaxVio
//!   trajectory is rising (short EWMA above long EWMA by
//!   `vio_margin`) — sustained concentration plus rising violation is
//!   the §1 routing-collapse signature, caught while it is still a
//!   drift.
//! * **scalar series** (batch MaxVio, queue depth, solver iterations,
//!   shed rate, replica sync divergence) — prequential robust-z
//!   against an EWMA mean/variance; a z above `z_threshold` after
//!   warmup raises the matching typed alert.
//!
//! Alerts are deduplicated with a per-(kind, layer) cooldown, counted
//! into `obs_alerts_total`, and dropped into the causal event ring so
//! an incident dump interleaves them with the routing events that
//! triggered them.

use crate::forecast::model::{Ewma, LoadForecaster};
use crate::obs::event::{self, EventKind};
use crate::telemetry::registry::{Counter, Gauge};
use crate::telemetry::{self, Snapshot};

/// Typed anomalies. Discriminants ride in event payloads and incident
/// files; keep them within `u8` and never reuse a retired value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertKind {
    /// sustained top-K concentration + rising MaxVio (paper §1)
    RoutingCollapse = 1,
    /// batch MaxVio robust-z spike
    MaxVioSpike = 2,
    /// solver iterations-per-solve robust-z spike
    SolverStall = 3,
    /// queue depth robust-z spike
    QueueSurge = 4,
    /// shed-rate robust-z spike
    ShedStorm = 5,
    /// replica merge-sync divergence robust-z spike
    SyncDivergence = 6,
}

const N_ALERT_KINDS: usize = 6;

impl AlertKind {
    pub const ALL: [AlertKind; N_ALERT_KINDS] = [
        AlertKind::RoutingCollapse,
        AlertKind::MaxVioSpike,
        AlertKind::SolverStall,
        AlertKind::QueueSurge,
        AlertKind::ShedStorm,
        AlertKind::SyncDivergence,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlertKind::RoutingCollapse => "routing_collapse",
            AlertKind::MaxVioSpike => "maxvio_spike",
            AlertKind::SolverStall => "solver_stall",
            AlertKind::QueueSurge => "queue_surge",
            AlertKind::ShedStorm => "shed_storm",
            AlertKind::SyncDivergence => "sync_divergence",
        }
    }

    pub fn from_u8(v: u8) -> Option<AlertKind> {
        Self::ALL.into_iter().find(|k| *k as u8 == v)
    }
}

/// One raised anomaly.
#[derive(Clone, Debug, PartialEq)]
pub struct Alert {
    pub kind: AlertKind,
    /// detector tick (1-based) at which the alert fired
    pub tick: u64,
    /// MoE layer the alert is about (collapse only; 0 otherwise)
    pub layer: u16,
    /// the score that crossed (robust-z, or top-K share for collapse)
    pub score: f64,
    /// raw series value behind the score
    pub value: f64,
    /// the threshold that was crossed
    pub threshold: f64,
    pub detail: String,
}

/// Detector thresholds. Defaults are sized for the serving sims: at
/// `m = 16`, `cf = 2.0`, uniform top-2 share is 0.125 and the
/// capacity-bounded collapsed top-2 share is 0.25, so 0.2 splits the
/// two regimes with margin on both sides.
#[derive(Clone, Debug)]
pub struct DetectorConfig {
    /// ticks before any alert may fire (baselines are still learning)
    pub warmup_ticks: u64,
    /// consecutive over-threshold ticks before collapse fires
    pub sustain_ticks: u64,
    /// hot-set size for the concentration score; 0 = `max(1, m/8)`
    pub hot_k: usize,
    /// top-`hot_k` share above which a layer counts as concentrated
    pub share_threshold: f64,
    /// short-EWMA MaxVio must exceed long-EWMA by this to call
    /// the trajectory "rising"
    pub vio_margin: f64,
    /// robust-z threshold for the scalar series
    pub z_threshold: f64,
    /// ticks a fired (kind, layer) stays silent before re-raising
    pub cooldown_ticks: u64,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        DetectorConfig {
            warmup_ticks: 3,
            sustain_ticks: 2,
            hot_k: 0,
            share_threshold: 0.2,
            vio_margin: 0.08,
            z_threshold: 4.0,
            cooldown_ticks: 8,
        }
    }
}

/// Prequential EWMA mean/variance for a scalar series; `z` is scored
/// against the state *before* the update (so a spike cannot mask
/// itself).
#[derive(Clone, Debug)]
struct EwmaStat {
    alpha: f64,
    mean: f64,
    var: f64,
    n: u64,
}

impl EwmaStat {
    fn new(alpha: f64) -> EwmaStat {
        EwmaStat { alpha, mean: 0.0, var: 0.0, n: 0 }
    }

    /// Robust-z of `x` against the running baseline, then fold `x` in.
    fn score_and_update(&mut self, x: f64) -> f64 {
        let z = if self.n < 2 {
            0.0
        } else {
            (x - self.mean) / (self.var.sqrt() + 1e-9)
        };
        let d = x - self.mean;
        self.mean += self.alpha * d;
        self.var =
            (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        self.n += 1;
        z
    }
}

/// Per-layer collapse tracking state.
struct LayerState {
    /// EWMA share baseline (the `forecast/` predictor)
    baseline: Ewma,
    /// consecutive ticks over the concentration threshold
    streak: u64,
    /// scratch for this tick's share vector
    shares: Vec<f64>,
}

/// The online detector. Feed it one [`Snapshot`] per tick via
/// [`Detector::tick`]; it returns the alerts raised at that tick.
pub struct Detector {
    cfg: DetectorConfig,
    tick: u64,
    /// previous cumulative `[layer][expert]` token grid
    prev_tokens: Vec<Vec<u64>>,
    layers: Vec<LayerState>,
    vio_short: f64,
    vio_long: f64,
    vio_n: u64,
    vio_z: EwmaStat,
    queue_z: EwmaStat,
    iters_z: EwmaStat,
    shed_z: EwmaStat,
    sync_z: EwmaStat,
    prev_shed: u64,
    /// tick at which (kind, layer) last fired, for cooldown
    fired: Vec<(AlertKind, u16, u64)>,
    /// total alerts raised over the detector's lifetime
    pub total_alerts: u64,
}

impl Detector {
    pub fn new(cfg: DetectorConfig) -> Detector {
        let alpha = 0.15;
        Detector {
            cfg,
            tick: 0,
            prev_tokens: Vec::new(),
            layers: Vec::new(),
            vio_short: 0.0,
            vio_long: 0.0,
            vio_n: 0,
            vio_z: EwmaStat::new(alpha),
            queue_z: EwmaStat::new(alpha),
            iters_z: EwmaStat::new(alpha),
            shed_z: EwmaStat::new(alpha),
            sync_z: EwmaStat::new(alpha),
            prev_shed: 0,
            fired: Vec::new(),
            total_alerts: 0,
        }
    }

    pub fn ticks(&self) -> u64 {
        self.tick
    }

    fn on_cooldown(&self, kind: AlertKind, layer: u16) -> bool {
        self.fired.iter().any(|&(k, l, at)| {
            k == kind
                && l == layer
                && self.tick.saturating_sub(at) < self.cfg.cooldown_ticks
        })
    }

    fn raise(
        &mut self,
        out: &mut Vec<Alert>,
        kind: AlertKind,
        layer: u16,
        score: f64,
        value: f64,
        threshold: f64,
        detail: String,
    ) {
        if self.tick <= self.cfg.warmup_ticks
            || self.on_cooldown(kind, layer)
        {
            return;
        }
        self.fired.retain(|&(k, l, _)| !(k == kind && l == layer));
        self.fired.push((kind, layer, self.tick));
        self.total_alerts += 1;
        telemetry::counter_add(Counter::ObsAlerts, 1);
        event::record_event(
            EventKind::Alert,
            self.tick,
            ((kind as u64) << 56) | ((layer as u64) << 40),
        );
        out.push(Alert {
            kind,
            tick: self.tick,
            layer,
            score,
            value,
            threshold,
            detail,
        });
    }

    /// Digest one snapshot; returns the alerts raised this tick.
    pub fn tick(&mut self, snap: &Snapshot) -> Vec<Alert> {
        self.tick += 1;
        let mut out = Vec::new();
        self.score_collapse(snap, &mut out);
        self.score_scalars(snap, &mut out);
        out
    }

    /// Concentration score of the hottest `hot_k` experts in a share
    /// vector (sum of the top-k fractions).
    fn top_k_share(shares: &[f64], k: usize) -> f64 {
        let mut top = vec![0.0f64; k];
        for &s in shares {
            let mut cand = s;
            for slot in top.iter_mut() {
                if cand > *slot {
                    std::mem::swap(&mut cand, slot);
                }
            }
        }
        top.iter().sum()
    }

    fn score_collapse(&mut self, snap: &Snapshot, out: &mut Vec<Alert>) {
        // MaxVio trajectory: short vs long EWMA of the batch gauge.
        let vio = snap.gauge(Gauge::RouterLastBatchVio);
        if self.vio_n == 0 {
            self.vio_short = vio;
            self.vio_long = vio;
        } else {
            self.vio_short += 0.4 * (vio - self.vio_short);
            self.vio_long += 0.05 * (vio - self.vio_long);
        }
        self.vio_n += 1;
        let vio_rising =
            self.vio_short > self.vio_long + self.cfg.vio_margin;

        let grid = &snap.expert_tokens;
        let mut worst_share = 0.0f64;
        for (l, row) in grid.iter().enumerate() {
            if l >= self.layers.len() {
                self.layers.push(LayerState {
                    baseline: Ewma::new(row.len().max(1), 0.3),
                    streak: 0,
                    shares: Vec::new(),
                });
            }
            let Some(st) = self.layers.get_mut(l) else { continue };
            let prev = self.prev_tokens.get(l);
            st.shares.clear();
            let mut total = 0u64;
            for (e, &cum) in row.iter().enumerate() {
                let before =
                    prev.and_then(|p| p.get(e)).copied().unwrap_or(0);
                let d = cum.saturating_sub(before);
                st.shares.push(d as f64);
                total += d;
            }
            if total == 0 {
                st.streak = 0;
                continue;
            }
            for s in st.shares.iter_mut() {
                *s /= total as f64;
            }
            let k = if self.cfg.hot_k == 0 {
                (st.shares.len() / 8).max(1)
            } else {
                self.cfg.hot_k
            };
            let obs = Self::top_k_share(&st.shares, k);
            let pred =
                Self::top_k_share(&st.baseline.forecast(1), k);
            st.baseline.observe(&st.shares);
            worst_share = worst_share.max(obs);
            let concentrated = obs > self.cfg.share_threshold
                && obs > pred * 1.05;
            if concentrated {
                st.streak += 1;
            } else {
                st.streak = 0;
            }
            if st.streak >= self.cfg.sustain_ticks && vio_rising {
                let detail = format!(
                    "layer {l}: top-{k} share {obs:.3} \
                     (baseline {pred:.3}) for {} ticks, \
                     MaxVio ewma {:.3} > {:.3}",
                    st.streak, self.vio_short, self.vio_long
                );
                self.raise(
                    out,
                    AlertKind::RoutingCollapse,
                    l.min(u16::MAX as usize) as u16,
                    obs,
                    vio,
                    self.cfg.share_threshold,
                    detail,
                );
            }
        }
        telemetry::gauge_set(Gauge::ObsCollapseScore, worst_share);
        self.prev_tokens.clear();
        self.prev_tokens.extend(grid.iter().cloned());
    }

    fn score_scalars(&mut self, snap: &Snapshot, out: &mut Vec<Alert>) {
        let zt = self.cfg.z_threshold;
        let vio = snap.gauge(Gauge::RouterLastBatchVio);
        let z = self.vio_z.score_and_update(vio);
        if z > zt && vio > 0.05 {
            self.raise(
                out,
                AlertKind::MaxVioSpike,
                0,
                z,
                vio,
                zt,
                format!("batch MaxVio {vio:.3} at z {z:.1}"),
            );
        }
        let depth = snap.gauge(Gauge::ServeQueueDepth);
        let z = self.queue_z.score_and_update(depth);
        if z > zt && depth >= 4.0 {
            self.raise(
                out,
                AlertKind::QueueSurge,
                0,
                z,
                depth,
                zt,
                format!("queue depth {depth:.0} at z {z:.1}"),
            );
        }
        let iters = snap.gauge(Gauge::SolverLastIters);
        let z = self.iters_z.score_and_update(iters);
        if z > zt && iters >= 1.0 {
            self.raise(
                out,
                AlertKind::SolverStall,
                0,
                z,
                iters,
                zt,
                format!("solver iterations {iters:.0} at z {z:.1}"),
            );
        }
        let shed = snap.counter(Counter::ServeShed);
        let shed_d = shed.saturating_sub(self.prev_shed) as f64;
        self.prev_shed = shed;
        let z = self.shed_z.score_and_update(shed_d);
        if z > zt && shed_d >= 2.0 {
            self.raise(
                out,
                AlertKind::ShedStorm,
                0,
                z,
                shed_d,
                zt,
                format!("{shed_d:.0} sheds this tick at z {z:.1}"),
            );
        }
        let div = snap.gauge(Gauge::ReplicaLastSyncDivergence);
        let z = self.sync_z.score_and_update(div);
        if z > zt && div > 0.05 {
            self.raise(
                out,
                AlertKind::SyncDivergence,
                0,
                z,
                div,
                zt,
                format!("sync divergence {div:.3} at z {z:.1}"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::Registry;
    use crate::telemetry::scrape;

    fn snap_with(reg: &Registry, vio: f64, loads: &[u32]) -> Snapshot {
        reg.gauge_set(Gauge::RouterLastBatchVio, vio);
        reg.expert_tokens_add(0, loads);
        scrape(reg)
    }

    #[test]
    fn alert_kinds_pack_into_a_byte_and_back() {
        for k in AlertKind::ALL {
            assert_eq!(AlertKind::from_u8(k as u8), Some(k));
            assert!(!k.name().is_empty());
        }
        assert_eq!(AlertKind::from_u8(0), None);
    }

    #[test]
    fn top_k_share_sums_the_hottest() {
        let shares = [0.1, 0.4, 0.05, 0.3, 0.15];
        assert!((Detector::top_k_share(&shares, 2) - 0.7).abs() < 1e-12);
        assert!((Detector::top_k_share(&shares, 1) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn balanced_shares_never_alert() {
        let reg = Registry::new();
        reg.set_enabled(true);
        let mut det = Detector::new(DetectorConfig::default());
        for _ in 0..20 {
            let s = snap_with(&reg, 0.01, &[100u32; 8]);
            assert!(det.tick(&s).is_empty());
        }
        assert_eq!(det.total_alerts, 0);
    }

    #[test]
    fn planted_concentration_with_rising_vio_fires_collapse() {
        let reg = Registry::new();
        reg.set_enabled(true);
        let mut det = Detector::new(DetectorConfig::default());
        // balanced warmup
        for _ in 0..6 {
            det.tick(&snap_with(&reg, 0.02, &[100u32; 8]));
        }
        // collapse: one expert swallows most of the layer, MaxVio climbs
        let mut fired = Vec::new();
        for t in 0..8 {
            let mut loads = [30u32; 8];
            loads[0] = 700;
            fired.extend(
                det.tick(&snap_with(&reg, 0.5 + 0.05 * t as f64, &loads)),
            );
        }
        assert!(
            fired.iter().any(|a| a.kind == AlertKind::RoutingCollapse),
            "collapse alert fired: {fired:?}"
        );
        let a = fired
            .iter()
            .find(|a| a.kind == AlertKind::RoutingCollapse)
            .expect("collapse alert");
        assert_eq!(a.layer, 0);
        assert!(a.score > 0.2);
    }

    #[test]
    fn cooldown_suppresses_rapid_refires() {
        let mut det = Detector::new(DetectorConfig {
            warmup_ticks: 0,
            cooldown_ticks: 100,
            ..DetectorConfig::default()
        });
        det.tick = 5;
        let mut out = Vec::new();
        det.raise(
            &mut out,
            AlertKind::QueueSurge,
            0,
            9.0,
            50.0,
            4.0,
            "t".into(),
        );
        det.raise(
            &mut out,
            AlertKind::QueueSurge,
            0,
            9.0,
            50.0,
            4.0,
            "t".into(),
        );
        assert_eq!(out.len(), 1);
    }
}
