//! Observability layer (ISSUE 8): causal event tracing, incident
//! flight recording, online anomaly detection, and the `bip-moe top`
//! dashboard — built on the `telemetry/` registry.
//!
//! * [`event`] — the zero-alloc causal event ring. Admission, shed,
//!   batch, per-layer routing, solver exit, replica dispatch and sync
//!   each drop a fixed-size record with request/batch/sync causal
//!   ids, so a MaxVio sample walks back to the decisions behind it.
//! * [`detect`] — EWMA/robust-z scoring over registry series with the
//!   routing-collapse early-warning rule (sustained top-K
//!   concentration + rising MaxVio, the paper-§1 failure signature).
//! * [`recorder`] — bounded event+scrape history dumped to a
//!   versioned "BIPI" incident file when a trigger fires; incidents
//!   link to the trace recorded alongside them for replay.
//! * [`top`] — the in-terminal dashboard renderer.
//!
//! [`ObsController`] wires the pieces into the serving loop: every
//! `tick_every` routed batches it scrapes the global registry, runs
//! one detector tick, and lets the flight recorder decide whether to
//! dump. `serve::run_scenario_observed` accepts one; `bip-moe serve
//! --obs-incidents DIR` builds one from the CLI.

pub mod detect;
pub mod event;
pub mod recorder;
pub mod top;

use std::path::PathBuf;

pub use detect::{Alert, AlertKind, Detector, DetectorConfig};
pub use event::{recent_events, EventKind, EventRecord};
pub use recorder::{
    FlightRecorder, Incident, IncidentHeader, RecorderConfig, Trigger,
    INCIDENT_MAGIC, INCIDENT_VERSION,
};
pub use top::TopState;

use crate::telemetry;

/// Controller knobs: how often to tick, and the detector/recorder
/// configuration underneath.
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// routed batches per detector tick
    pub tick_every: u64,
    pub detector: DetectorConfig,
    pub recorder: RecorderConfig,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            tick_every: 32,
            detector: DetectorConfig::default(),
            recorder: RecorderConfig::default(),
        }
    }
}

/// The serve-loop hook: scrape → detect → maybe dump, once every
/// `tick_every` batches.
pub struct ObsController {
    tick_every: u64,
    detector: Detector,
    recorder: FlightRecorder,
    batches: u64,
    /// every alert raised over the run, in tick order
    pub alerts: Vec<Alert>,
    /// every incident file dumped over the run
    pub incidents: Vec<PathBuf>,
}

impl ObsController {
    pub fn new(cfg: ObsConfig) -> ObsController {
        ObsController {
            tick_every: cfg.tick_every.max(1),
            detector: Detector::new(cfg.detector),
            recorder: FlightRecorder::new(cfg.recorder),
            batches: 0,
            alerts: Vec::new(),
            incidents: Vec::new(),
        }
    }

    /// Count one routed batch; runs a detector tick every
    /// `tick_every` calls.
    pub fn on_batch(&mut self) {
        self.batches += 1;
        if self.batches % self.tick_every != 0 {
            return;
        }
        self.force_tick();
    }

    /// Run one detector tick now (the serve loop calls this once more
    /// at drain so short runs still get a final verdict).
    pub fn force_tick(&mut self) {
        let snap = telemetry::scrape(telemetry::global());
        let alerts = self.detector.tick(&snap);
        if let Some(p) =
            self.recorder.observe(self.detector.ticks(), &snap, &alerts)
        {
            self.incidents.push(p);
        }
        self.alerts.extend(alerts);
    }

    pub fn ticks(&self) -> u64 {
        self.detector.ticks()
    }
}
