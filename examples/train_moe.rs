//! End-to-end validation driver (EXPERIMENTS.md §E2E): pre-train a real
//! MoE transformer LM through the full three-layer stack — rust
//! coordinator -> PJRT -> AOT'd JAX/Pallas train step — for a few hundred
//! steps on the synthetic corpus, logging the loss curve, the per-step
//! MaxVio, held-out perplexity, and the simulated cluster time.
//!
//!   cargo run --release --example train_moe            # moe16-bench
//!   BIP_MOE_CONFIG=moe16 BIP_MOE_STEPS=300 \
//!   cargo run --release --example train_moe            # ~35M params
//!
//! Trains BIP (T=4) and the Loss-Controlled baseline back to back so the
//! balance/quality/time comparison is visible in one run.

use std::path::Path;

use bip_moe::metrics::table::ascii_plot;
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;

fn main() -> anyhow::Result<()> {
    bip_moe::util::log::init_from_env();
    let config = std::env::var("BIP_MOE_CONFIG")
        .unwrap_or_else(|_| "moe16-bench".to_string());
    let steps: u64 = std::env::var("BIP_MOE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let engine = Engine::new(Path::new("artifacts"))?;
    let cfg = engine.manifest().config(&config)?.clone();
    println!(
        "e2e: config={config} ({} params, {} layers x {} experts, \
         top-{k}, {n} tokens/batch), {steps} steps",
        cfg.theta_size,
        cfg.n_layers,
        cfg.n_experts,
        k = cfg.top_k,
        n = cfg.n_tokens
    );

    let mut table = TablePrinter::new(
        &format!("e2e pre-training: {config}, {steps} steps"),
        &["mode", "first loss", "final loss", "test ppl", "AvgMaxVio",
          "SupMaxVio", "sim h (full)", "wall s"],
    );
    let mut curves: Vec<(String, Vec<f32>)> = Vec::new();

    for (label, mode, t) in
        [("bip T=4", "bip", 4usize), ("loss-controlled", "aux", 0)]
    {
        let mut driver = TrainDriver::new(&config, mode, t, steps);
        driver.eval_batches = 16;
        let outcome = driver.run(&engine)?;
        let out = outcome.dump(Path::new("reports"))?;
        let losses = &outcome.recorder.loss_series;
        table.row(vec![
            label.to_string(),
            format!("{:.4}", losses.first().unwrap()),
            format!("{:.4}", losses.last().unwrap()),
            format!("{:.4}", outcome.perplexity),
            format!("{:.4}", outcome.recorder.balance.avg_max_vio()),
            format!("{:.4}", outcome.recorder.balance.sup_max_vio()),
            format!("{:.3}", outcome.sim.extrapolate_hours(
                cfg.total_steps as u64)),
            format!("{:.1}", outcome.recorder.total_wall()),
        ]);
        curves.push((
            format!("{label} loss"),
            losses.clone(),
        ));
        curves.push((
            format!("{label} maxvio"),
            outcome.recorder.balance.global_series.clone(),
        ));
        println!("reports: {}", out.display());
        // persist the trained model
        let ckpt = format!("reports/{}_e2e.ckpt",
                           driver.run_label());
        outcome.state.save(Path::new(&ckpt), &config, mode)?;
        println!("checkpoint: {ckpt}");
    }

    println!("\nloss curves (both modes) + MaxVio:");
    let plot: Vec<(&str, &[f32])> = curves
        .iter()
        .map(|(l, s)| (l.as_str(), s.as_slice()))
        .collect();
    print!("{}", ascii_plot(&plot, 76, 18));
    table.print();

    println!(
        "\nvalidation: loss falls from ~ln(V)={:.2}; bip AvgMaxVio stays \
         near 0 from step 1; aux baseline shows the unbalanced transient \
         and a higher simulated cluster time.",
        (cfg.vocab_size as f64).ln()
    );
    Ok(())
}
