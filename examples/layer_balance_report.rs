//! Per-layer balance report (the Appendix A view): trains the tiny model
//! briefly with each routing mode and prints AvgMaxVio for EVERY MoE
//! layer plus an ASCII rendition of the per-layer MaxVio trajectories —
//! the paper's claim is that BIP balances *every* layer, not just the
//! aggregate.
//!
//!   cargo run --release --example layer_balance_report
//!   BIP_MOE_CONFIG=moe16-bench BIP_MOE_STEPS=80 cargo run --release \
//!       --example layer_balance_report

use std::path::Path;

use bip_moe::metrics::table::ascii_plot;
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;

fn main() -> anyhow::Result<()> {
    bip_moe::util::log::init_from_env();
    let config = std::env::var("BIP_MOE_CONFIG")
        .unwrap_or_else(|_| "tiny".to_string());
    let steps: u64 = std::env::var("BIP_MOE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let engine = Engine::new(Path::new("artifacts"))?;
    let n_layers = engine.manifest().config(&config)?.n_layers;

    let mut headers = vec!["mode".to_string()];
    for l in 1..=n_layers {
        headers.push(format!("L{l}"));
    }
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = TablePrinter::new(
        &format!("per-layer AvgMaxVio — {config}, {steps} steps"),
        &headers_ref,
    );

    let mut bip_series: Option<Vec<Vec<f32>>> = None;
    for (mode, t) in [("aux", 0usize), ("lossfree", 0), ("bip", 4)] {
        let mut driver = TrainDriver::new(&config, mode, t, steps);
        driver.eval_batches = 1;
        let outcome = driver.run(&engine)?;
        let mut row = vec![mode.to_string()];
        for l in 0..n_layers {
            row.push(format!("{:.3}",
                             outcome.recorder.balance.layer_avg(l)));
        }
        table.row(row);
        if mode == "bip" {
            bip_series = Some(outcome.recorder.balance.series.clone());
        }
    }
    table.print();

    if let Some(series) = bip_series {
        println!("BIP per-layer MaxVio over steps (all layers overlaid):");
        let named: Vec<(String, &[f32])> = series
            .iter()
            .enumerate()
            .map(|(l, s)| (format!("L{}", l + 1), s.as_slice()))
            .collect();
        let plot: Vec<(&str, &[f32])> =
            named.iter().map(|(n, s)| (n.as_str(), *s)).collect();
        print!("{}", ascii_plot(&plot, 72, 12));
        println!("every layer's line should hug the bottom of the plot.");
    }
    Ok(())
}
