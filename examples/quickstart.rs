//! Quickstart: the BIP-Based Balancing algorithm in 60 seconds.
//!
//!   cargo run --release --example quickstart
//!
//! Part 1 needs no artifacts: it builds a skewed routing instance (the
//! situation that collapses MoE training), routes it greedily, then with
//! Algorithm 1's dual ascent, and compares against the exact optimum.
//!
//! Part 2 (when `make artifacts` has been run) takes one real PJRT
//! training step on the tiny MoE LM with each routing mode and shows the
//! per-layer expert loads — balance from the very first step.

use std::path::Path;

use bip_moe::bip::{dual, flow, greedy_topk, Instance};
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::{Engine, Tensor};
use bip_moe::train::state::TrainState;
use bip_moe::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // ---- Part 1: the algorithm itself --------------------------------
    let (n, m, k) = (512usize, 16usize, 4usize);
    let mut rng = Pcg64::new(0);
    // skew=3: every token prefers the same few experts — the hard case
    let inst = Instance::synthetic(n, m, k, 2.0, 3.0, &mut rng);

    let greedy = greedy_topk(&inst);
    let (bip, q) = dual::solve(&inst, 4);
    let (exact, exact_obj) = flow::solve_exact(&inst);

    let mut table = TablePrinter::new(
        &format!("routing one batch: n={n} tokens, m={m} experts, k={k}"),
        &["router", "score kept", "MaxVio", "max expert load"],
    );
    for (name, routing, obj) in [
        ("greedy top-k", &greedy, greedy.objective(&inst)),
        ("BIP-Based Balancing (T=4)", &bip, bip.objective(&inst)),
        ("exact optimum (min-cost flow)", &exact, exact_obj),
    ] {
        let loads = routing.loads(m);
        table.row(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * obj / greedy.objective(&inst)),
            format!("{:.3}", routing.max_violation(&inst)),
            format!("{} (mean {})", loads.iter().max().unwrap(),
                    n * k / m),
        ]);
    }
    table.print();
    println!("expert duals q (nonzero = congested expert): {:?}\n",
             q.iter().map(|x| (x * 1000.0).round() / 1000.0)
              .collect::<Vec<_>>());

    // ---- Part 2: one real training step via PJRT ---------------------
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("(run `make artifacts` to also demo the PJRT train step)");
        return Ok(());
    }
    let engine = Engine::new(artifacts)?;
    let cfg = engine.manifest().config("tiny")?.clone();
    let init = engine.manifest().find("tiny", "init", "-", None)?.clone();
    let theta = engine.run(&init, &[Tensor::scalar_i32(0)])?.pop().unwrap();

    let mut rng = Pcg64::new(1);
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size as u64) as i32)
        .collect();
    let tokens =
        Tensor::from_i32(&[cfg.batch_size, cfg.seq_len + 1], tokens);

    let mut table = TablePrinter::new(
        "first REAL training step (tiny MoE LM, layer-1 expert loads)",
        &["mode", "loss/token", "layer-1 loads", "MaxVio"],
    );
    for (mode, t) in [("aux", 0usize), ("lossfree", 0), ("bip", 4)] {
        let art = engine.manifest().train_artifact("tiny", mode, t)?;
        let mut state = TrainState::fresh(theta.clone(), &cfg);
        let outs = engine.run(art, &state.as_inputs(tokens.clone()))?;
        let rest = state.absorb(outs);
        let nll = rest[0].scalar_f32()?;
        let loads = &rest[1].f32s()?[..cfg.n_experts];
        let mean = (cfg.n_tokens * cfg.top_k) as f32 / cfg.n_experts as f32;
        let maxvio =
            loads.iter().cloned().fold(0.0f32, f32::max) / mean - 1.0;
        table.row(vec![
            mode.to_string(),
            format!("{:.4}", nll / cfg.n_tokens as f32),
            format!("{:?}", loads.iter().map(|&x| x as u32)
                    .collect::<Vec<_>>()),
            format!("{maxvio:.3}"),
        ]);
    }
    table.print();
    println!("note the bip row: balanced at step 1, no warmup needed.");
    Ok(())
}
