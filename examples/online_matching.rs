//! §5 application demo: multi-slot online advertisement matching.
//!
//!   cargo run --release --example online_matching
//!
//! A stream of page views arrives; each page shows `slots` ads out of
//! `ads` advertisers with known CTRs. We maximize total expected clicks
//! while capping any single advertiser's share (problem (BIP) with
//! advertisers as experts). Shows greedy vs Algorithm 3 (exact online)
//! vs Algorithm 4 (constant-space approximation), against the hindsight
//! optimum from the min-cost-flow solver.

use bip_moe::matching::simulator::{run_policy, MatchPolicy, Workload};
use bip_moe::metrics::TablePrinter;

fn main() {
    let (flows, ads, slots) = (8192usize, 32usize, 2usize);
    let w = Workload::synthetic(flows, ads, slots, 42);
    println!(
        "workload: {flows} page views, {ads} advertisers, {slots} slots \
         per page, per-advertiser cap {} impressions\n",
        w.capacity()
    );

    let mut table = TablePrinter::new(
        "online ad matching",
        &["policy", "expected clicks", "vs hindsight opt", "MaxVio",
          "state bytes", "note"],
    );
    let rows = [
        (MatchPolicy::Greedy,
         "ignores caps -> hot advertisers flooded"),
        (MatchPolicy::Online { t_iters: 4 },
         "Algorithm 3: per-advertiser heaps"),
        (MatchPolicy::Approx { t_iters: 4, buckets: 128 },
         "Algorithm 4: O(m*b) histograms"),
    ];
    for (policy, note) in rows {
        let r = run_policy(&w, policy);
        table.row(vec![
            r.policy.clone(),
            format!("{:.1}", r.objective),
            format!("{:.3}", r.competitive_ratio),
            format!("{:.3}", r.max_violation),
            r.state_bytes.to_string(),
            note.to_string(),
        ]);
    }
    table.print();

    // the steady-state picture: violation of the LAST quarter of the
    // stream, after the online duals have warmed up
    println!("steady-state check (last 25% of the stream):");
    for t_iters in [1usize, 4, 8] {
        let mut gate =
            bip_moe::bip::online::OnlineGate::new(ads, slots,
                                                  w.capacity(), t_iters);
        let mut tail = vec![0u64; ads];
        for i in 0..flows {
            let chosen = gate.route_token(w.row(i));
            if i >= 3 * flows / 4 {
                for &e in &chosen {
                    tail[e as usize] += 1;
                }
            }
        }
        let mean = (flows / 4 * slots) as f64 / ads as f64;
        let vio = *tail.iter().max().unwrap() as f64 / mean - 1.0;
        println!("  T={t_iters}: tail MaxVio {vio:.3}");
    }
    println!(
        "\ntakeaway: Algorithm 4 matches Algorithm 3's quality with \
         stream-length-independent memory — deployable at recommendation \
         scale (§5.2)."
    );
}
